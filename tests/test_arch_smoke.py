"""Per-architecture smoke tests on REDUCED configs (brief requirement):
instantiate, run one forward + one train step on CPU, assert shapes and
finiteness; additionally check decode-vs-forward consistency (teacher-forced
decode must reproduce full-forward logits) for every decoder family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.data import synthetic_batch
from repro.models import (abstract_params, cache_struct, decode_step, forward,
                          init_params, loss_fn, model_struct, param_count)
from repro.models.base import init_params as init_struct_params

B, S = 2, 16


def make(arch):
    cfg = get_config(arch, smoke=True)
    struct = model_struct(cfg)
    params = init_params(struct, jax.random.PRNGKey(0))
    seq = S + (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, B, seq).items()}
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg, params, batch = make(arch)
    logits, aux, _ = jax.jit(
        lambda p, b: forward(p, cfg, b))(params, batch)
    total = S + (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_decreases_nothing_nan(arch):
    cfg, params, batch = make(arch)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, b), has_aux=True)(p)
        p2 = jax.tree_util.tree_map(lambda w, g: w - 1e-2 * g, p, grads)
        return loss, metrics, p2

    loss0, metrics, params = step(params, batch)
    assert bool(jnp.isfinite(loss0)), f"{arch} loss not finite"
    loss1, *_ = step(params, batch)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0) + 1.0     # no explosion


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if get_config(a).is_decoder
                                  and get_config(a).frontend == "token"])
def test_decode_matches_forward(arch):
    """Teacher-forced single-step decode must reproduce the full forward
    logits — validates KV ring caches, recurrent states and token shifts."""
    cfg, params, batch = make(arch)
    tokens = batch["tokens"]
    logits_full, _, _ = forward(params, cfg, batch)

    cstruct = cache_struct(cfg, B, S)
    caches = [init_struct_params(cs, jax.random.PRNGKey(1))
              for cs in cstruct]

    dec = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i))
    outs = []
    for i in range(S):
        lg, caches = dec(params, caches, tokens[:, i:i + 1],
                         jnp.asarray(i, jnp.int32))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_count_full_config_sane(arch):
    """The FULL config must build its structure (no allocation) and land in
    the right parameter-count ballpark."""
    cfg = get_config(arch)
    struct = model_struct(cfg)
    n = param_count(struct)
    expected_min = {
        "hubert-xlarge": 0.8e9, "gemma3-4b": 3e9, "minitron-4b": 3.5e9,
        "internlm2-20b": 17e9, "llama3.2-1b": 1.0e9,
        "recurrentgemma-2b": 2e9, "internvl2-2b": 1.5e9,
        "mixtral-8x7b": 40e9, "deepseek-moe-16b": 14e9, "rwkv6-3b": 2.5e9,
    }[arch]
    assert n > expected_min, f"{arch}: {n/1e9:.2f}B params"
    assert n < expected_min * 3.5
    abstract_params(struct)          # ShapeDtypeStruct tree builds


def test_scan_vs_unrolled_equivalence():
    """scan_layers=False (unrolled) must match the scanned forward."""
    cfg, params, batch = make("gemma3-4b")
    l1, _, _ = forward(params, cfg, batch)
    l2, _, _ = forward(params, cfg.replace(scan_layers=False), batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=1e-5,
                               atol=1e-5)


def test_remat_equivalence():
    cfg, params, batch = make("llama3.2-1b")
    l1, _, _ = forward(params, cfg, batch)
    l2, _, _ = forward(params, cfg.replace(remat="full"), batch)
    l3, _, _ = forward(params, cfg.replace(remat="dots"), batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l3), rtol=1e-5,
                               atol=1e-5)


def test_rwkv_chunked_matmul_equivalence():
    """Chunked-parallel wkv (per-chunk matmuls) == per-token scan."""
    import numpy as np
    cfg, params, _ = make("rwkv6-3b")
    batch = {k: jnp.asarray(v)
             for k, v in __import__("repro.data", fromlist=["synthetic_batch"])
             .synthetic_batch(cfg, 2, 64).items()}
    l1, _, _ = forward(params, cfg, batch)
    l2, _, _ = forward(params, cfg.replace(rwkv_impl="chunked",
                                           rwkv_chunk=16), batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=2e-3, atol=2e-3)
    # non-multiple chunk falls back to the scan (still correct)
    l3, _, _ = forward(params, cfg.replace(rwkv_impl="chunked",
                                           rwkv_chunk=48), batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l3, np.float32),
                               rtol=2e-3, atol=2e-3)
