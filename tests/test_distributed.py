"""Multi-device integration tests.

These spawn subprocesses with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the flag must be set before jax initializes, and the main test process must
keep its single-device view), then run REAL sharded computation on an 8-way
host-device mesh: training steps under pjit, checkpoint save -> elastic
restore onto a different mesh shape, and the compressed all-reduce collective.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8) -> dict:
    """Run ``body`` (python source) in a subprocess; it must print a JSON
    object on its last stdout line."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_mesh_and_sharded_train_step():
    """A smoke model trains under a real (data=4, model=2) mesh; loss must
    decrease and params stay sharded."""
    res = run_sub("""
        from repro.launch.train import train
        res = train("llama3.2-1b", smoke=True, steps=8, batch=8, seq=32,
                    lr=1e-3, log_every=1000, model_axis=2)
        p = jax.tree_util.tree_leaves(res["params"])[3]
        print(json.dumps({
            "first": res["losses"][0], "last": res["losses"][-1],
            "n_shards": len(p.addressable_shards),
            "devices": len(jax.devices())}))
    """)
    assert res["devices"] == 8
    assert res["last"] < res["first"]
    assert res["n_shards"] == 8


def test_checkpoint_elastic_reshard(tmp_path):
    """Save on a (4, 2) mesh, restore onto a (2, 2) survivors mesh (node
    loss dropped one DP row), verify values and new sharding."""
    res = run_sub(f"""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.runtime import survivors_mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
        save_checkpoint({str(tmp_path)!r}, 5, {{"x": xs}})
        # node failure: only 4 devices survive; model axis must stay whole
        new_mesh = survivors_mesh(jax.devices()[:4], ("data", "model"), 2)
        out = restore_checkpoint(
            {str(tmp_path)!r}, 5, {{"x": x}},
            shardings={{"x": NamedSharding(new_mesh, P("data", "model"))}})
        ok = bool(jnp.all(out["x"] == x))
        print(json.dumps({{
            "ok": ok,
            "new_shards": len(out["x"].addressable_shards),
            "mesh_shape": list(new_mesesh.devices.shape)
                if False else list(new_mesh.devices.shape)}}))
    """)
    assert res["ok"]
    assert res["new_shards"] == 4
    assert res["mesh_shape"] == [2, 2]


def test_compressed_allreduce_collective():
    """shard_map int8 two-phase all-reduce matches the f32 sum within
    quantization error, on a real 8-device axis."""
    res = run_sub("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.runtime import compressed_allreduce
        mesh = jax.make_mesh((8,), ("data",))
        xs = [jax.random.normal(jax.random.PRNGKey(i), (4096,))
              for i in range(1)]
        x = xs[0]
        out = compressed_allreduce(x, mesh, axis="data")
        # every shard holds the same replicated x -> allreduce = 8 * x
        want = 8.0 * x
        err = float(jnp.max(jnp.abs(out - want)))
        rel = err / float(jnp.max(jnp.abs(want)))
        print(json.dumps({"rel_err": rel}))
    """)
    assert res["rel_err"] < 0.05


def test_dryrun_entry_on_small_mesh():
    """The dry-run path itself (lower+compile+analyze) on an 8-device mesh —
    catches sharding/analysis regressions quickly."""
    res = run_sub("""
        from jax.sharding import Mesh
        from repro.launch.steps import build_cell, lower_cell
        from repro.launch.hlo_analysis import analyze_compiled
        import numpy as np
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        import repro.configs as C
        cfg = C.get_config("llama3.2-1b", smoke=True)
        # monkeypatch a small shape through the cell builder
        from repro.launch import steps
        import repro.configs
        repro.configs.SHAPES["tiny_train"] = C.Shape("tiny_train", 64, 8,
                                                     "train")
        orig = repro.configs.get_config
        def patched(name, smoke=False):
            return orig(name, smoke=True)
        steps.get_config = patched
        cell = steps.build_cell("llama3.2-1b", "tiny_train", mesh)
        compiled = lower_cell(cell, mesh).compile()
        roof = analyze_compiled(compiled)
        print(json.dumps({
            "flops": roof.flops, "coll": roof.coll_bytes,
            "dominant": roof.dominant}))
    """)
    assert res["flops"] > 0
    assert res["coll"] > 0
