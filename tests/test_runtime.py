"""Fault-tolerance and distributed-optimization substrate tests (single
process; multi-device integration lives in test_distributed.py)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import DataConfig, SyntheticPipeline, synthetic_batch
from repro.configs import get_config
from repro.runtime import (StragglerMonitor, dequantize_int8,
                           ef_compress_grads, quantize_int8,
                           rebalance_batches)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"w": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                  "s": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = make_tree()
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(str(tmp_path), 7, tree)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), tree, out)


def test_checkpoint_partial_never_loads(tmp_path):
    tree = make_tree()
    d = save_checkpoint(str(tmp_path), 3, tree)
    os.remove(os.path.join(d, "COMMIT"))     # simulate crash mid-write
    assert latest_step(str(tmp_path)) is None


def test_checkpoint_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = make_tree()
    for s in (10, 20, 30, 40):
        mgr.save(s, tree)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 40
    kept = sorted(os.listdir(str(tmp_path)))
    assert "step_000000030" in kept and "step_000000040" in kept
    assert "step_000000010" not in kept


def test_train_restart_resume_bitexact(tmp_path):
    """Kill at step 30, resume from the last checkpoint, reach the same state
    as an uninterrupted run (determinism of pipeline + optimizer)."""
    from repro.launch.train import train
    kw = dict(smoke=True, steps=24, batch=4, seq=32, ckpt_every=8,
              lr=1e-3, log_every=1000)
    full = train("llama3.2-1b", ckpt_dir=None, **kw)

    ck = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="injected failure"):
        train("llama3.2-1b", ckpt_dir=ck, fail_at_step=18, **kw)
    assert latest_step(ck) == 16
    resumed = train("llama3.2-1b", ckpt_dir=ck, resume=True, **kw)
    np.testing.assert_allclose(full["losses"][-1], resumed["losses"][-1],
                               rtol=1e-4, atol=1e-5)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-4),
        full["params"], resumed["params"])


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_host_slicing():
    cfg = get_config("llama3.2-1b", smoke=True)
    a = synthetic_batch(cfg, 8, 32, step=5)
    b = synthetic_batch(cfg, 8, 32, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(cfg, 8, 32, step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding: two hosts see disjoint halves of the same global batch
    p0 = SyntheticPipeline(cfg, 8, 32, host_index=0, host_count=2)
    p1 = SyntheticPipeline(cfg, 8, 32, host_index=1, host_count=2)
    g = synthetic_batch(cfg, 8, 32, step=3)
    np.testing.assert_array_equal(p0.get(3)["tokens"], g["tokens"][:4])
    np.testing.assert_array_equal(p1.get(3)["tokens"], g["tokens"][4:])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_telescopes():
    """Sum of EF-compressed gradients converges to the true gradient sum."""
    g = jax.random.normal(jax.random.PRNGKey(1), (256,)) * 0.1
    err = None
    applied = jnp.zeros_like(g)
    for _ in range(50):
        comp, err = ef_compress_grads(g, err)
        applied = applied + comp
    np.testing.assert_allclose(np.asarray(applied / 50), np.asarray(g),
                               atol=1e-3)


def test_ef_training_matches_uncompressed():
    """EF-compressed SGD reaches (almost) the uncompressed optimum on a
    quadratic."""
    A = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    A = A @ A.T / 8 + jnp.eye(8)
    b = jax.random.normal(jax.random.PRNGKey(3), (8,))

    def gradf(x):
        return A @ x - b

    def run(compress):
        x = jnp.zeros(8)
        err = None
        for _ in range(300):
            g = gradf(x)
            if compress:
                g, err = ef_compress_grads(g, err)
            x = x - 0.1 * g
        return x

    x_plain, x_comp = run(False), run(True)
    np.testing.assert_allclose(np.asarray(x_comp), np.asarray(x_plain),
                               atol=5e-3)


# ---------------------------------------------------------------------------
# straggler mitigation
# ---------------------------------------------------------------------------

def test_straggler_detection():
    mon = StragglerMonitor(window=10, threshold=1.5)
    for _ in range(10):
        for h in range(8):
            mon.record(h, 1.0 if h != 5 else 2.5)
    assert mon.stragglers() == [5]


def test_rebalance_preserves_total_and_starves_none():
    speeds = {0: 1.0, 1: 1.0, 2: 0.4, 3: 1.2}
    alloc = rebalance_batches(64, speeds, quantum=2)
    assert sum(alloc.values()) == 64
    assert all(v >= 2 for v in alloc.values())
    assert alloc[2] < alloc[0] <= alloc[3]


def test_train_with_compression_converges():
    from repro.launch.train import train
    res = train("llama3.2-1b", smoke=True, steps=40, batch=4, seq=32,
                compress=True, lr=1e-2, log_every=1000)
    assert np.isfinite(res["losses"][-1])
    assert np.mean(res["losses"][-5:]) < np.mean(res["losses"][:5])
