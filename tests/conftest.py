"""Shared pytest configuration for the repro test suite."""


def pytest_addoption(parser):
    parser.addoption(
        "--regen-goldens", action="store_true", default=False,
        help="rewrite the golden-trace fixtures under tests/goldens/ from "
             "the current engines instead of diffing against them")
