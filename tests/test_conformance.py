"""Differential conformance: every registered mechanism, one contract.

The paper's correctness criterion (SS VIII): a control-flow-management
mechanism may schedule lanes however it likes, but on race-free programs
the final architectural state must be exactly what the pre-Volta baseline
computes.  This suite enforces that *differentially* across the whole
registry (``iter_mechanisms()``), so any future ``@register_mechanism``
plugin — DARM-style melding, decoupled control flow, ... — is held to the
bar automatically:

* over the shared benchmark suite (race-free members) and over random
  ``tests/progen.py`` programs, final ``regs`` / ``mem`` / ``finished``
  must agree with ``simt_stack`` wherever BOTH mechanisms report
  ``SimStatus.OK``.  Register comparison excludes ``BMOV B->R`` spill
  destinations: those hold microarchitectural reconvergence masks on the
  stack machines and are (correctly) never written by stackless or
  NOP-ing mechanisms;
* on synchronization-heavy programs (``sync_features=True``: spinlocks,
  WARPSYNC joins, BREAK loops with nested Whiles) the pre-Volta baseline
  deadlocks — there the stack mechanisms cross-check each other and the
  per-thread-PC scheduler, with ``hanoi`` as the reference;
* progress properties: ``volta_itps`` must terminate (never a structural
  ``DEADLOCK``) on every generated program that ``turing_oracle``
  finishes — the Volta forward-progress guarantee — including the
  spinlock programs that hang ``simt_stack`` and YIELD-less Hanoi.

The JAX engine participates through the suite half only: running it over
hundreds of random programs re-JITs per shape bucket for minutes, and its
bit-exactness against ``hanoi`` is already property-tested in
``test_hanoi_jax.py``.
"""
import numpy as np
import pytest

from repro.core import MachineConfig
from repro.core.isa import F_DST, F_OP, Op
from repro.core.programs import (make_suite, spinlock_no_yield_program,
                                 spinlock_program)
from repro.engine import SimStatus, Simulator, as_request, iter_mechanisms
from tests.progen import CHECK_REGS, COUNTER_CELL, W, make_program

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=20_000)
SUITE = make_suite(CFG, datasets=1)
SIM = Simulator("simt_stack")

ALL_MECHANISMS = [m.name for m in iter_mechanisms()]
NUMPY_MECHANISMS = [m.name for m in iter_mechanisms() if m.backend != "jax"]

PROGEN_SEEDS = list(range(10))
SYNC_SEEDS = list(range(12))
TERMINATION_SEEDS = list(range(30))


def _bmov_spill_regs(program) -> set[int]:
    """Register columns that receive Bx spills (mechanism-internal state)."""
    prog = np.asarray(program)
    return {int(prog[pc, F_DST]) for pc in range(prog.shape[0])
            if int(prog[pc, F_OP]) == Op.BMOV_B2R}


def _assert_state_agrees(res, base, *, check_regs=None, program=None,
                         who=""):
    assert res.finished == base.finished, f"{who}: finished masks differ"
    np.testing.assert_array_equal(res.mem, base.mem,
                                  err_msg=f"{who}: memory differs")
    if check_regs is None:
        ncols = res.regs.shape[1]
        check_regs = [r for r in range(ncols)
                      if r not in _bmov_spill_regs(program)]
    np.testing.assert_array_equal(
        res.regs[:, check_regs], base.regs[:, check_regs],
        err_msg=f"{who}: architectural registers differ")


# ---------------------------------------------------------------------------
# shared benchmark suite: everyone vs the pre-Volta baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mech", ALL_MECHANISMS)
@pytest.mark.parametrize("bench", [b for b in SUITE if b.race_free],
                         ids=lambda b: b.name)
def test_suite_conformance(bench, mech):
    base = SIM.run(bench, CFG, mechanism="simt_stack")
    res = SIM.run(bench, CFG, mechanism=mech)
    if not (base.ok and res.ok):
        pytest.skip(f"not comparable: {mech}={res.status.value} "
                    f"baseline={base.status.value}")
    _assert_state_agrees(res, base, program=bench.program,
                         who=f"{bench.name}/{mech}")


@pytest.mark.parametrize("mech", ALL_MECHANISMS)
def test_suite_mechanisms_complete_race_free_programs(mech):
    """No registered mechanism may be vacuously conformant: every one must
    actually finish the deadlock-free structured suite."""
    for bench in SUITE:
        if not bench.race_free:
            continue
        res = SIM.run(bench, CFG, mechanism=mech)
        assert res.ok, f"{mech} failed {bench.name}: {res.status.value}"


# ---------------------------------------------------------------------------
# random structured programs (historical distribution)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mech", NUMPY_MECHANISMS)
@pytest.mark.parametrize("seed", PROGEN_SEEDS)
def test_progen_conformance(seed, mech):
    built, cfg = make_program(seed, 8)
    if built is None:
        pytest.skip("rejected program shape")
    prog, mem = built
    base = SIM.run(prog, cfg, mechanism="simt_stack", init_mem=mem)
    res = SIM.run(prog, cfg, mechanism=mech, init_mem=mem)
    assert base.ok, "historical progen programs are deadlock-free pre-Volta"
    if not res.ok:
        pytest.skip(f"not comparable: {mech}={res.status.value}")
    _assert_state_agrees(res, base, check_regs=CHECK_REGS,
                         who=f"seed {seed}/{mech}")


# ---------------------------------------------------------------------------
# synchronization-heavy programs: spinlocks, WARPSYNC joins, nested BREAKs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mech", NUMPY_MECHANISMS)
@pytest.mark.parametrize("seed", SYNC_SEEDS)
def test_sync_progen_conformance(seed, mech):
    """On lock-bearing programs simt_stack hangs by design, so ``hanoi``
    (the paper's correct mechanism) anchors the differential check; the
    "agree wherever both OK" contract is unchanged."""
    built, cfg = make_program(seed, 8, sync_features=True)
    if built is None:
        pytest.skip("rejected program shape")
    prog, mem = built
    base = SIM.run(prog, cfg, mechanism="hanoi", init_mem=mem)
    res = SIM.run(prog, cfg, mechanism=mech, init_mem=mem)
    if not (base.ok and res.ok):
        pytest.skip(f"not comparable: {mech}={res.status.value} "
                    f"hanoi={base.status.value}")
    _assert_state_agrees(res, base, check_regs=CHECK_REGS,
                         who=f"sync seed {seed}/{mech}")
    assert int(res.mem[COUNTER_CELL]) == W, \
        f"{mech}: spinlock mutual exclusion violated"


def test_sync_programs_exercise_the_prevolta_gap():
    """Sanity for the distribution itself: the sync-feature programs must
    actually hit the paper's gap — pre-Volta hangs, Hanoi completes."""
    prevolta_hangs = hanoi_completes = 0
    for seed in SYNC_SEEDS:
        built, cfg = make_program(seed, 8, sync_features=True)
        if built is None:
            continue
        prog, mem = built
        if not SIM.run(prog, cfg, init_mem=mem).ok:
            prevolta_hangs += 1
        if SIM.run(prog, cfg, mechanism="hanoi", init_mem=mem).ok:
            hanoi_completes += 1
    assert prevolta_hangs >= len(SYNC_SEEDS) // 2
    assert hanoi_completes >= len(SYNC_SEEDS) // 2


# ---------------------------------------------------------------------------
# forward-progress properties of the per-thread-PC scheduler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", TERMINATION_SEEDS)
def test_volta_terminates_where_oracle_finishes(seed):
    """The Volta progress guarantee as a property: on every generated
    synchronization-heavy program that ``turing_oracle`` finishes,
    ``volta_itps`` must never report a structural DEADLOCK (and, fuel
    being equal, must in fact finish)."""
    built, cfg = make_program(seed, 8, sync_features=True)
    if built is None:
        pytest.skip("rejected program shape")
    prog, mem = built
    oracle = SIM.run(prog, cfg, mechanism="turing_oracle", init_mem=mem)
    if not oracle.ok:
        pytest.skip(f"oracle itself: {oracle.status.value}")
    volta = SIM.run(prog, cfg, mechanism="volta_itps", init_mem=mem)
    assert volta.status is not SimStatus.DEADLOCK
    assert volta.ok, f"volta_itps: {volta.status.value}"


@pytest.mark.parametrize("prog_fn, name", [
    (spinlock_program, "spinlock"),
    (spinlock_no_yield_program, "spinlock_no_yield"),
])
def test_volta_completes_spinlocks_where_stack_machines_hang(prog_fn, name):
    """The acceptance scenario: both spinlock variants terminate under
    independent thread scheduling; pre-Volta hangs on both, and even Hanoi
    hangs without YIELD (paper SS V-G) — volta_itps needs neither YIELD
    nor a reconvergence stack, only the progress guarantee."""
    prog = prog_fn()
    volta = SIM.run(prog, CFG, mechanism="volta_itps")
    assert volta.ok and int(volta.mem[1]) == CFG.n_threads
    assert not SIM.run(prog, CFG, mechanism="simt_stack").ok
    if name == "spinlock_no_yield":
        assert not SIM.run(prog, CFG, mechanism="hanoi").ok


def test_volta_structural_deadlock_is_flagged_not_burned():
    """A WARPSYNC whose mask can never assemble (half the warp EXITs first)
    is a *structural* deadlock: volta_itps must report DEADLOCK with fuel
    to spare, not spin the budget away."""
    from repro.core.asm import assemble
    full = (1 << CFG.n_threads) - 1
    prog = assemble(f"""
        LANEID R1
        ISETP.GE P0, R1, {CFG.n_threads // 2}
        @P0 EXIT                 ; upper half leaves without syncing
        WARPSYNC {full}          ; waits for lanes that already exited? no:
        EXIT                     ; finished lanes count as arrived
    """)
    r = SIM.run(prog, CFG, mechanism="volta_itps")
    assert r.ok      # exited lanes satisfy the rendezvous

    prog2 = assemble(f"""
        LANEID R1
        ISETP.GE P0, R1, {CFG.n_threads // 2}
        @P0 BRA other
        WARPSYNC {full}          ; lower half parks here...
        EXIT
    other:
        WARPSYNC {full}          ; ...upper half parks THERE: split rendezvous
        EXIT
    """)
    r2 = SIM.run(prog2, CFG, mechanism="volta_itps")
    assert r2.status is SimStatus.DEADLOCK
    assert r2.fuel_left > 0, "structural deadlock must not burn the budget"


def test_volta_divergent_warpsync_masks_union_not_overwrite():
    """Two groups reaching one WARPSYNC pc with different register-operand
    masks (UB on real hardware): the rendezvous must take the UNION of the
    masks, so a later narrow-mask arrival can never spring earlier parked
    lanes out of a rendezvous that never assembled."""
    from repro.core.asm import assemble
    cfg = MachineConfig(n_threads=4, max_steps=512)
    prog = assemble("""
        LANEID R1
        ISETP.EQ P1, R1, 1
        @P1 BRA spin         ; lane 1 never arrives, never exits
        MOV R2, 14           ; lanes 2,3 will demand {1,2,3}
        ISETP.EQ P0, R1, 0
        @P0 MOV R2, 1        ; lane 0 demands only {0}
        @P0 BRA slow         ; lane 0 arrives at the sync second
    sync:
        WARPSYNC R2
        EXIT
    slow:
        NOP
        BRA sync
    spin:
        BRA spin
    """)
    r = SIM.run(prog, cfg, mechanism="volta_itps")
    # lanes 2,3 park demanding lane 1; lane 0's later {0}-mask arrival must
    # NOT release them (or itself) -- nobody may reach EXIT
    assert r.status is SimStatus.OUT_OF_FUEL
    assert r.finished == 0, \
        "a narrow-mask arrival released lanes from an unassembled rendezvous"


# ---------------------------------------------------------------------------
# the acceptance-criteria surface, end to end
# ---------------------------------------------------------------------------

def test_compare_volta_against_oracle_baseline():
    """``Simulator.compare("volta_itps", baseline="turing_oracle")`` over
    (a slice of) the benchmark suite: every row computed, every status OK
    on race-free programs, and the per-thread-PC schedule genuinely
    diverges from the stack schedule."""
    benches = [b for b in SUITE if b.race_free][:6]
    report = SIM.compare("volta_itps", benches, CFG,
                         baseline="turing_oracle", timing=False)
    rows = report.pair("volta_itps", "turing_oracle")
    assert len(rows) == len(benches)
    assert all(r.status_a == "ok" and r.status_b == "ok" for r in rows)
    assert any(r.discrepancy > 0 for r in rows)


def test_sm_interleave_conforms_and_aggregates():
    bench = next(b for b in SUITE if b.name == "RBFS0")
    res = SIM.run(bench, CFG, mechanism="sm_interleave",
                  meta={"sm_warps": 3, "sm_inner": "hanoi"})
    base = SIM.run(bench, CFG, mechanism="hanoi")
    _assert_state_agrees(res, base, program=bench.program,
                         who="sm_interleave")
    sm = res.meta["sm"]
    assert sm.n_warps == 3 and sm.inner == "hanoi"
    assert sm.steps == 3 * len(base.trace)
    assert len(res.trace) == sm.steps


def test_sm_rejects_nesting_itself():
    """Both nesting routes are errors — explicit ``inner=`` on run_sm and
    ``sm_inner`` meta on the registered mechanism; only a Simulator whose
    *default* happens to be sm_interleave falls back to hanoi."""
    bench = next(b for b in SUITE if b.name == "DIAMOND")
    with pytest.raises(ValueError, match="single-warp"):
        SIM.run_sm(bench, CFG, inner="sm_interleave")
    with pytest.raises(ValueError, match="single-warp"):
        SIM.run(bench, CFG, mechanism="sm_interleave",
                meta={"sm_inner": "sm_interleave"})
    sm = Simulator("sm_interleave").run_sm(bench, CFG, n_warps=2)
    assert sm.inner == "hanoi" and sm.ok
