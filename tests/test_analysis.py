"""repro.analysis: static verifier, CFG fingerprints, and their wiring.

Four layers under test:

* the full-opcode CFG builder + CALL/RET interprocedural edges (shared
  regression against ``repro.core.cfg.immediate_postdominators``);
* the conformance gate — every suite + progen program (all feature
  distributions) analyzes with zero errors, and each known-bad fixture
  triggers exactly its intended diagnostic;
* assembler/analyzer diagnostics — AsmError source line/column context,
  and ``(pc, disassembled line)`` on every Diagnostic, round-tripped
  through assemble/disassemble;
* platform wiring — ``Simulator.run(verify=...)``, service admission
  rejection (no shard dispatch, ``rejected`` stat), archive fingerprints
  and ``rank_similar`` / the ``similar`` CLI.
"""
import json

import numpy as np
import pytest

from repro.analysis import (FEATURES, ProgramCFG, Severity,
                            StaticAnalysisError, analyze_program, distance,
                            fingerprint, fingerprint_meta, verify_program)
from repro.core import programs as P
from repro.core.asm import AsmError, assemble, disassemble, disassemble_line
from repro.core.cfg import immediate_postdominators
from repro.core.isa import F_OP, MachineConfig, Op
from repro.core.programs import make_suite
from tests.progen import corpus

W8 = MachineConfig(n_threads=8)


def codes(report):
    return [d.code for d in report.diagnostics]


# ---------------------------------------------------------------------------
# CFG builder + CALL/RET edge regression (satellite 1)
# ---------------------------------------------------------------------------

def calls_benchmark():
    bench = next(b for b in make_suite(W8) if b.name == "CALLS")
    return bench.program


def test_call_site_ipdom_is_callsync_not_sink():
    # pre-fix, calls had no return edge to pc+1, so everything downstream
    # of a call site post-dominated nothing and IPDoms collapsed to SINK
    prog = calls_benchmark()
    ipdoms = immediate_postdominators(prog)
    bsync_pcs = [pc for pc in range(prog.shape[0])
                 if int(prog[pc, F_OP]) == Op.BSYNC]
    assert ipdoms, "CALLS has conditional branches"
    for pc, ipdom in ipdoms.items():
        assert ipdom in bsync_pcs, (
            f"branch at pc {pc}: IPDom {ipdom} should be a BSYNC "
            f"(reconvergence downstream of the call site), not SINK")


def test_predicated_call_has_fall_through_edge():
    prog = assemble("""
        LANEID R1
        ISETP.GE P0, R1, 2
        @P0 CALL f
        EXIT
    f:
        MOV R9, 4
        RET R9
    """)
    g = ProgramCFG(prog)
    assert sorted(g.succs[2]) == [3, 4]      # callee AND fall-through
    # RET returns to the call continuation, not the virtual sink
    assert g.succs[5] == [3]


def test_branch_ipdoms_match_core_cfg_everywhere():
    progs = [b.program for b in make_suite(W8)]
    progs += [prog for _, prog, _ in corpus(20)]
    for prog in progs:
        assert ProgramCFG(prog).branch_ipdoms == \
            immediate_postdominators(prog)


def test_bad_control_target_is_redirected_not_fatal():
    g = ProgramCFG(assemble("BRA 99"))
    assert g.bad_targets == [0]
    assert g.succs[0] == [g.sink]


# ---------------------------------------------------------------------------
# conformance gate (satellite 2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bench", make_suite(W8), ids=lambda b: b.name)
def test_suite_program_has_zero_errors(bench):
    report = analyze_program(bench.program, W8, name=bench.name)
    assert report.ok, report.render()
    assert not report.warnings, report.render()


def test_progen_corpus_all_distributions_zero_errors():
    triples = corpus(40)
    assert len(triples) > 80, "corpus unexpectedly small"
    for label, prog, cfg in triples:
        report = analyze_program(prog, cfg, name=label)
        assert report.ok, report.render()


def test_yieldless_spinlock_triggers_exactly_spin_loop_warning():
    report = analyze_program(P.spinlock_no_yield_program(), W8)
    assert codes(report) == ["spin-loop"]
    assert report.diagnostics[0].severity is Severity.WARN
    # ... and the YIELD-ful original is completely clean
    assert not analyze_program(P.spinlock_program(), W8).diagnostics


def test_fig6_break_is_info_removing_it_is_error():
    with_break = analyze_program(P.fig6_program(), W8)
    assert with_break.ok
    assert set(codes(with_break)) == {"early-reconvergence"}
    without = analyze_program(P.fig6_no_break_program(), W8)
    assert not without.ok
    assert all(c == "reconvergence" for c in codes(without))


def test_warpsync_split_rendezvous_is_error():
    split = assemble("""
        LANEID R1
        ISETP.GE P0, R1, 2
        @P0 BRA x
        WARPSYNC 15
        BRA j
    x:
        WARPSYNC 15
    j:
        EXIT
    """)
    report = analyze_program(split, MachineConfig(n_threads=4))
    assert "warpsync-split" in codes(report)
    assert not report.ok
    # single shared rendezvous: legal (only the unannotated-branch info)
    good = analyze_program(P.warpsync_program(4), MachineConfig(n_threads=4))
    assert good.ok
    assert codes(good) == ["unannotated-branch"]


def test_bad_target_diagnostic():
    report = analyze_program(assemble("BRA 99"))
    assert codes(report) == ["bad-target"]
    assert not report.ok


def test_bssy_target_must_be_matching_bsync():
    not_bsync = assemble("BSSY B0, 2\nNOP\nNOP\nEXIT")
    assert "bssy-target" in codes(analyze_program(not_bsync))
    wrong_bx = assemble("BSSY B0, 2\nNOP\nBSYNC B1\nEXIT")
    assert "bssy-target" in codes(analyze_program(wrong_bx))


def test_bx_out_of_range_is_error():
    report = analyze_program(assemble("BSYNC B9\nEXIT"),
                             MachineConfig(n_bx=8))
    assert "bad-bx" in codes(report)


def test_fig5_without_spill_is_bx_clobber():
    clobbered = FIG5_NO_SPILL = P.FIG5_ASM.replace(
        "    BMOV R0, B0         ; spill: R0 <- B0  (Fig 5 step 2)", "    NOP")
    assert "BMOV R0, B0" not in FIG5_NO_SPILL
    report = analyze_program(assemble(clobbered), W8)
    assert "bx-clobber" in codes(report)
    # the real Fig 5 (with the spill) is clean
    assert analyze_program(P.fig5_program(), W8).ok


def test_unreachable_and_fall_off_end_warnings():
    report = analyze_program(assemble("""
        BRA done
        MOV R1, 1
        MOV R2, 2
    done:
        MOV R3, 3
    """))
    cs = codes(report)
    assert "unreachable" in cs and "fall-off-end" in cs
    assert report.ok          # warnings, not errors


def test_infinite_loop_warning():
    report = analyze_program(assemble("loop:\nMOV R1, 1\nBRA loop"))
    assert "infinite-loop" in codes(report)


def test_verify_program_raises_with_report_attached():
    with pytest.raises(StaticAnalysisError) as exc_info:
        verify_program(P.fig6_no_break_program(), W8, name="fig6nb")
    report = exc_info.value.report
    assert report.name == "fig6nb"
    assert not report.ok
    assert "reconvergence" in str(exc_info.value)
    # strict promotes warnings to failures
    verify_program(P.spinlock_no_yield_program(), W8)        # ok: warn only
    with pytest.raises(StaticAnalysisError):
        verify_program(P.spinlock_no_yield_program(), W8, strict=True)


# ---------------------------------------------------------------------------
# assembler + diagnostic source context (satellite 3)
# ---------------------------------------------------------------------------

def test_asm_error_carries_line_col_and_caret():
    src = "    MOV R1, 1\n    BRA nowhere\n    EXIT"
    with pytest.raises(AsmError) as exc_info:
        assemble(src)
    err = exc_info.value
    assert err.lineno == 2
    assert err.col == src.splitlines()[1].find("nowhere") + 1
    assert err.source == "    BRA nowhere"
    rendered = str(err)
    assert "line 2" in rendered and "^" in rendered


def test_asm_error_missing_operand_names_line():
    with pytest.raises(AsmError) as exc_info:
        assemble("MOV R1, 1\nBRA")
    err = exc_info.value
    assert err.lineno == 2
    assert "missing operand" in err.reason


def test_asm_error_bad_guard_has_context():
    with pytest.raises(AsmError) as exc_info:
        assemble("@Q0 MOV R1, 1")
    assert exc_info.value.lineno == 1
    assert "bad predicate" in exc_info.value.reason


def test_diagnostics_quote_disassembled_instruction():
    prog = P.fig6_no_break_program()
    report = analyze_program(prog, W8)
    assert report.diagnostics
    for d in report.diagnostics:
        assert d.line == disassemble_line(prog[d.pc])
        assert d.line            # non-empty
        # the pc-prefixed form appears verbatim in the full disassembly
        assert f"{d.pc:4d}: {d.line}" in disassemble(prog)


def test_disassemble_line_roundtrip_via_disassemble():
    prog = P.fig5_program()
    lines = disassemble(prog).splitlines()
    assert len(lines) == prog.shape[0]
    for pc, row in enumerate(prog):
        assert lines[pc] == f"{pc:4d}: {disassemble_line(row)}"


def test_lint_cli_reports_pc_and_disasm(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.asm"
    bad.write_text(P.FIG6_NO_BREAK_ASM)
    assert main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "[error] reconvergence" in out
    prog = P.fig6_no_break_program()
    for d in analyze_program(prog, W8).errors:
        assert f"pc {d.pc:4d}" in out
        assert disassemble_line(prog[d.pc]) in out


def test_lint_cli_json_and_strict(tmp_path, capsys):
    from repro.analysis.__main__ import main
    spin = tmp_path / "spin.asm"
    spin.write_text(P.SPINLOCK_NO_YIELD_ASM)
    assert main([str(spin), "--json"]) == 0          # warn only: passes
    obj = json.loads(capsys.readouterr().out)
    assert obj["ok"] and [d["code"] for d in obj["diagnostics"]] == \
        ["spin-loop"]
    assert set(obj["fingerprint"]["features"]) == set(FEATURES)
    assert main([str(spin), "--strict"]) == 1        # strict: warn fails
    capsys.readouterr()


def test_lint_cli_asm_error_exit_2(tmp_path, capsys):
    from repro.analysis.__main__ import main
    broken = tmp_path / "broken.asm"
    broken.write_text("BRA nowhere\n")
    with pytest.raises(SystemExit) as exc_info:
        main([str(broken)])
    assert exc_info.value.code == 2
    assert "line 1" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------

def test_fingerprint_shape_and_self_distance():
    fp = fingerprint(P.spinlock_program())
    assert len(fp) == len(FEATURES)
    assert distance(fp, fp) == 0.0
    other = fingerprint(P.diamond_program())
    d = distance(fp, other)
    assert 0.0 < d <= 1.0
    assert d == distance(other, fp)          # symmetric


def test_fingerprint_meta_roundtrips_through_json():
    meta = fingerprint_meta(P.fig5_program())
    back = json.loads(json.dumps(meta))
    assert tuple(back["f"]) == fingerprint(P.fig5_program())


def test_fingerprint_distinguishes_structures():
    # a loopy atomic program sits far from a straight-line diamond; the
    # same program re-encoded is at 0
    spin = fingerprint(P.spinlock_program())
    spin2 = fingerprint(assemble(P.SPINLOCK_ASM))
    assert distance(spin, spin2) == 0.0
    assert distance(spin, fingerprint(P.diamond_program())) > 0.1


# ---------------------------------------------------------------------------
# platform wiring: Simulator verify / service admission / archive similar
# ---------------------------------------------------------------------------

def test_simulator_verify_flag():
    from repro.engine import Simulator
    sim = Simulator("hanoi")
    bad = P.fig6_no_break_program()
    # default: permissive — broken programs are runnable on purpose
    res = sim.run(bad, W8)
    assert res is not None
    with pytest.raises(StaticAnalysisError):
        sim.run(bad, W8, verify=True)
    with pytest.raises(StaticAnalysisError):
        sim.run_batch([P.diamond_program(), bad], W8, verify=True)
    # constructor default applies when the call site doesn't override
    strict_sim = Simulator("hanoi", verify=True)
    with pytest.raises(StaticAnalysisError):
        strict_sim.run(bad, W8)
    # explicit verify=False bypasses the constructor default — the broken
    # program runs (and, being broken, exhausts its fuel instead of exiting)
    assert strict_sim.run(bad, W8, verify=False).status is not None


def test_service_rejects_statically_invalid_at_admission():
    from repro.service import SimulationService
    bad = P.fig6_no_break_program()
    good = P.fig6_program()
    with SimulationService(default_mechanism="hanoi", workers=1) as svc:
        t_bad = svc.submit(bad, W8, name="bad")
        t_good = svc.submit(good, W8, name="good")
        svc.flush()
        assert t_good.result(30).ok
        exc = t_bad.exception(5)
        assert isinstance(exc, StaticAnalysisError)
        assert [d.code for d in exc.report.errors] == \
            ["reconvergence", "reconvergence"]
        stats = svc.stats()
    assert stats.rejected == 1
    assert stats.submitted == 2
    assert stats.completed == 1          # the rejected one never dispatched
    assert stats.failed == 0


def test_service_rejects_bad_sm_cell():
    from repro.service import SimulationService
    with SimulationService(default_mechanism="hanoi", workers=1) as svc:
        t = svc.submit_sm(P.fig6_no_break_program(), W8, n_warps=2,
                          inner="hanoi")
        assert isinstance(t.exception(5), StaticAnalysisError)
        stats = svc.stats()
    assert stats.rejected == 2           # counted in warps, like submitted
    assert stats.sm_jobs == 0


def test_service_verify_off_admits_everything():
    from repro.service import SimulationService
    with SimulationService(default_mechanism="hanoi", workers=1,
                           verify=False) as svc:
        t = svc.submit(P.fig6_no_break_program(), W8)
        svc.flush()
        res = t.result(30)               # runs (and deadlocks) for real
        assert res is not None
        assert svc.stats().rejected == 0


def _write_archive(tmp_path):
    from repro.engine import Simulator
    from repro.engine.sinks import RotatingJsonlSink
    d = str(tmp_path / "arch")
    sink = RotatingJsonlSink(d)
    sim = Simulator("hanoi", sink=sink)
    for name, prog in [("spin", P.spinlock_program()),
                       ("fig5", P.fig5_program()),
                       ("fig6", P.fig6_program()),
                       ("diamond", P.diamond_program())]:
        sim.run(prog, W8, name=name, record_trace=True)
    sink.flush()
    sink.close()
    return d


def test_archive_index_carries_fingerprints(tmp_path):
    from repro.archive import ArchiveIndex
    d = _write_archive(tmp_path)
    idx = ArchiveIndex.ensure(d)
    assert len(idx) == 4
    for e in idx.entries:
        assert e.fp is not None and len(e.fp) == len(FEATURES)
    # stamped fp == recomputed fp (the begin-meta stamp is authoritative)
    assert idx.entries[0].fp == fingerprint(P.spinlock_program())


def test_rank_similar_self_match_first_at_zero(tmp_path):
    from repro.archive import ArchiveIndex
    d = _write_archive(tmp_path)
    idx = ArchiveIndex.ensure(d)
    for e in idx.entries:
        ranked = idx.rank_similar(e.fp)
        assert ranked[0] == (e.run_id, 0.0)
        assert len(ranked) == len(idx)
        assert all(ranked[i][1] <= ranked[i + 1][1]
                   for i in range(len(ranked) - 1))


def test_similar_cli_by_run_id_and_asm(tmp_path, capsys):
    from repro.archive.__main__ import main
    d = _write_archive(tmp_path)
    assert main(["similar", d, "--to", "run-000001", "--top", "2"]) == 0
    out = capsys.readouterr().out
    assert "run-000001  d=0.0000" in out
    # query by .asm file: the archived spinlock run is its 0-distance match
    q = tmp_path / "q.asm"
    q.write_text(P.SPINLOCK_ASM)
    assert main(["similar", d, "--to", str(q), "--json"]) == 0
    obj = json.loads(capsys.readouterr().out)
    assert obj["ranked"][0] == {"id": "run-000000", "distance": 0.0}


def test_similar_cli_unknown_run_id(tmp_path, capsys):
    from repro.archive.__main__ import main
    d = _write_archive(tmp_path)
    assert main(["similar", d, "--to", "run-999999"]) == 1
    assert "unknown run id" in capsys.readouterr().err


def test_old_sidecar_version_transparently_rebuilt(tmp_path):
    from repro.archive import ArchiveIndex
    from repro.archive.index import INDEX_KIND, index_path
    d = _write_archive(tmp_path)
    idx = ArchiveIndex.ensure(d)
    # forge a v1 sidecar (pre-fingerprint): load() must refuse it and
    # ensure() must rebuild with fingerprints filled in
    header = {"kind": INDEX_KIND, "version": 1, "prefix": "traces",
              "files": [list(f) for f in idx.files], "runs": len(idx)}
    with open(index_path(d), "w", encoding="utf-8") as fh:
        fh.write(json.dumps(header) + "\n")
        for e in idx.entries:
            row = e.to_json()
            del row["fp"]
            fh.write(json.dumps(row) + "\n")
    assert ArchiveIndex.load(d) is None
    rebuilt = ArchiveIndex.ensure(d)
    assert all(e.fp is not None for e in rebuilt.entries)
