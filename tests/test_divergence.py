"""Tile-granularity divergence layer: census invariants + consistency with
the Pallas kernel's schedule-time predicates."""
import numpy as np
import pytest

# compat shim: without hypothesis only the @given tests skip, the
# example-based census tests still run
from tests.hypothesis_compat import given, settings, st

from repro.core.divergence import (EMPTY, FULL, PARTIAL, MaskSpec, census,
                                   classify_grid, schedule_order)
from repro.kernels import tile_stats


@settings(max_examples=30, deadline=None)
@given(sq=st.sampled_from([256, 1024, 4096]),
       w=st.sampled_from([0, 128, 512, 1024]),
       causal=st.booleans(),
       bq=st.sampled_from([64, 128]))
def test_census_matches_kernel_tile_stats(sq, w, causal, bq):
    g = classify_grid(sq, sq, MaskSpec(causal=causal, window=w), bq=bq, bk=bq)
    c = census(g)
    k = tile_stats(sq, sq, causal=causal, window=w, bq=bq, bk=bq)
    assert c["empty"] == k["empty"]
    assert c["partial"] == k["partial"]
    assert c["full"] == k["full"]


def test_diagonal_always_live():
    g = classify_grid(1024, 1024, MaskSpec(causal=True, window=64))
    for i in range(g.shape[0]):
        assert g[i, i] != EMPTY


def test_window_bounds_kept_work():
    """Windowed attention keeps O(S*w) tiles: kept fraction ~ w/S."""
    S, w = 32768, 1024
    c = census(classify_grid(S, S, MaskSpec(causal=True, window=w)))
    upper = (2 * w / S) + 0.02
    assert c["flops_kept_frac"] <= upper


def test_schedule_order_majority_first():
    g = classify_grid(512, 512, MaskSpec(causal=True))
    order = schedule_order(g)
    assert len(order) == census(g)["full"] + census(g)["partial"]
    # within each row, FULL tiles come before PARTIAL ones
    by_row = {}
    for i, j in order:
        by_row.setdefault(i, []).append(g[i, j])
    for vals in by_row.values():
        seen_partial = False
        for v in vals:
            if v == PARTIAL:
                seen_partial = True
            assert not (seen_partial and v == FULL)


def test_kv_padding_tail_is_empty():
    g = classify_grid(256, 512, MaskSpec(causal=False, kv_len=256))
    assert (g[:, 2:] == EMPTY).all()
    assert (g[:, :2] == FULL).all()
