"""Step-by-step validation of the paper's walkthrough figures on the
numpy reference interpreter, driven through the canonical ``repro.engine``
API (the ``interp.run_*`` entry points are deprecated shims)."""
import numpy as np
import pytest

from repro.core import (MachineConfig, Op, assemble, immediate_postdominators,
                        run_reference)
from repro.core.programs import (diamond_program, fig5_program,
                                 fig6_no_break_program, fig6_program,
                                 warpsync_program)
from repro.engine import Simulator

CFG4 = MachineConfig(n_threads=4, max_steps=512)
SIM = Simulator("hanoi")


def masks_of(trace, pc):
    return [m for p, m in trace if p == pc]


# ---------------------------------------------------------------------------
# Fig 1 diamond: divergence + reconvergence, basic
# ---------------------------------------------------------------------------

def test_diamond_hanoi():
    r = SIM.run(diamond_program(), CFG4)
    assert not r.deadlocked and r.error is None
    assert r.finished == 0b1111
    # threads 0,1 took the 'taken' path (lane < 2)
    np.testing.assert_array_equal(r.regs[:, 2], [111, 111, 200, 200])
    np.testing.assert_array_equal(r.regs[:, 3], [112, 112, 201, 201])
    # after reconvergence the post-join instruction runs with the full mask
    prog = diamond_program()
    join_pc = prog.shape[0] - 2     # IADDI before EXIT
    assert masks_of(r.trace, join_pc) == [0b1111]


def test_diamond_simt_stack_matches():
    prog = diamond_program()
    h = SIM.run(prog, CFG4)
    s = SIM.run(prog, CFG4, mechanism="simt_stack")
    assert not s.deadlocked
    np.testing.assert_array_equal(h.regs, s.regs)
    np.testing.assert_array_equal(h.mem, s.mem)


def test_diamond_matches_reference():
    prog = diamond_program()
    h = SIM.run(prog, CFG4)
    ref = run_reference(prog, CFG4)
    np.testing.assert_array_equal(h.regs, ref.regs)


# ---------------------------------------------------------------------------
# Fig 5: nested divergence, B0 shared by two reconvergence points via BMOV
# ---------------------------------------------------------------------------

def test_fig5_results():
    r = SIM.run(fig5_program(), CFG4)
    assert not r.deadlocked and r.error is None
    assert r.finished == 0b1111
    np.testing.assert_array_equal(r.regs[:, 2], [100, 100, 20, 30])
    # R3=5 only for threads 2,3 (the E tail after the inner reconvergence)
    np.testing.assert_array_equal(r.regs[:, 3], [0, 0, 5, 5])
    # R0 holds the spilled outer reconvergence mask 0b1111 on every thread
    # that executed the BMOV (all of them)
    np.testing.assert_array_equal(r.regs[:, 0], [15, 15, 15, 15])


def test_fig5_reconvergence_masks():
    prog = fig5_program()
    r = SIM.run(prog, CFG4)
    # find the 'MOV R3, 5' (E tail) and the EXIT: E tail must run with mask
    # 0b1100 (threads 2,3 reunited), EXIT with the full mask.
    mov5_pc = next(pc for pc in range(prog.shape[0])
                   if prog[pc, 0] == Op.MOV and prog[pc, 5] == 5)
    assert masks_of(r.trace, mov5_pc) == [0b1100]
    exit_pc = prog.shape[0] - 1
    assert masks_of(r.trace, exit_pc) == [0b1111]


def test_fig5_matches_reference():
    prog = fig5_program()
    h = SIM.run(prog, CFG4)
    ref = run_reference(prog, CFG4)
    np.testing.assert_array_equal(h.regs[:, 2:4], ref.regs[:, 2:4])


# ---------------------------------------------------------------------------
# Fig 6: early reconvergence (before IPDom) enabled by BREAK
# ---------------------------------------------------------------------------

def test_fig6_early_reconvergence():
    prog = fig6_program()
    r = SIM.run(prog, CFG4)
    assert not r.deadlocked and r.error is None
    assert r.finished == 0b1111
    np.testing.assert_array_equal(r.regs[:, 2], [0, 7, 7, 7])    # B body
    np.testing.assert_array_equal(r.regs[:, 3], [0, 8, 8, 8])    # B tail
    np.testing.assert_array_equal(r.regs[:, 4], [9, 9, 9, 9])    # D tail
    # early reconvergence: the B tail (MOV R3, 8) ran ONCE with mask 0b1110,
    # i.e. threads 1,2,3 were reunited before the IPDom at D.
    mov8_pc = next(pc for pc in range(prog.shape[0])
                   if prog[pc, 0] == Op.MOV and prog[pc, 5] == 8)
    assert masks_of(r.trace, mov8_pc) == [0b1110]
    mov9_pc = next(pc for pc in range(prog.shape[0])
                   if prog[pc, 0] == Op.MOV and prog[pc, 5] == 9)
    assert masks_of(r.trace, mov9_pc) == [0b1111]


def test_fig6_without_break_deadlocks():
    """SS VI-B: remove the BREAK and the BSYNC at B waits for thread 0
    forever."""
    r = SIM.run(fig6_no_break_program(), CFG4)
    assert r.deadlocked


# ---------------------------------------------------------------------------
# WARPSYNC (SS V-F, SS VII-B): reconvergence without a prior BSSY
# ---------------------------------------------------------------------------

def test_warpsync_reunites():
    prog = warpsync_program(4)
    r = SIM.run(prog, CFG4)
    assert not r.deadlocked and r.error is None
    np.testing.assert_array_equal(r.regs[:, 2], [1, 1, 2, 2])
    np.testing.assert_array_equal(r.regs[:, 3], [9, 9, 9, 9])
    mov9_pc = next(pc for pc in range(prog.shape[0])
                   if prog[pc, 0] == Op.MOV and prog[pc, 5] == 9)
    assert masks_of(r.trace, mov9_pc) == [0b1111]


def test_warpsync_register_operand():
    prog = assemble("""
    LANEID R1
    MOV R5, 15
    ISETP.GE P0, R1, 2
    @P0 BRA x
    MOV R2, 1
    BRA w
x:
    MOV R2, 2
w:
    WARPSYNC R5
    MOV R3, 9
    EXIT
""")
    r = SIM.run(prog, CFG4)
    assert not r.deadlocked
    np.testing.assert_array_equal(r.regs[:, 3], [9, 9, 9, 9])


# ---------------------------------------------------------------------------
# predication (SS V-A): dual predicates, negation, predicated EXIT
# ---------------------------------------------------------------------------

def test_dual_predicates_and_semantics():
    prog = assemble("""
    LANEID R1
    ISETP.GE P0, R1, 1      ; P0: lanes 1,2,3
    ISETP.GE P1, R1, 3      ; P1: lane 3
    @P0 MOV R2, 5           ; lanes 1,2,3
    @!P0 MOV R2, 6          ; lane 0
    @P0 IADDI R3, R2, 10    ; guard 1: P0
    @P0 BRA !P1, tgt        ; branch iff P0 & !P1 -> lanes 1,2
    MOV R4, 1               ; lanes 0,3
    BRA end
tgt:
    MOV R4, 2               ; lanes 1,2
end:
    EXIT
""")
    r = SIM.run(prog, CFG4)
    np.testing.assert_array_equal(r.regs[:, 2], [6, 5, 5, 5])
    np.testing.assert_array_equal(r.regs[:, 3], [0, 15, 15, 15])
    np.testing.assert_array_equal(r.regs[:, 4], [1, 2, 2, 1])


def test_predicated_exit():
    """SS V-B: masked threads continue from the subsequent instruction."""
    prog = assemble("""
    LANEID R1
    ISETP.LT P0, R1, 2
    @P0 EXIT                ; lanes 0,1 terminate
    MOV R2, 7               ; lanes 2,3 continue
    EXIT
""")
    r = SIM.run(prog, CFG4)
    assert not r.deadlocked
    assert r.finished == 0b1111
    np.testing.assert_array_equal(r.regs[:, 2], [0, 0, 7, 7])


def test_exit_strips_bx_masks():
    """SS VII-A: EXIT removes finished threads from every valid Bx register,
    so a pending reconvergence does not wait for them."""
    prog = assemble("""
    LANEID R1
    BSSY B0, sync
    ISETP.GE P0, R1, 2
    @P0 BRA quit
    MOV R2, 3               ; lanes 0,1
    BRA sync
quit:
    EXIT                    ; lanes 2,3 exit inside the region
sync:
    BSYNC B0
    MOV R3, 4               ; must still run for lanes 0,1
    EXIT
""")
    r = SIM.run(prog, CFG4)
    assert not r.deadlocked
    assert r.finished == 0b1111
    np.testing.assert_array_equal(r.regs[:, 3], [4, 4, 0, 0])


# ---------------------------------------------------------------------------
# IPDom analysis sanity (pre-Volta compiler assist)
# ---------------------------------------------------------------------------

def test_ipdom_of_diamond():
    prog = diamond_program()
    ipd = immediate_postdominators(prog)
    bra_pc = next(pc for pc in range(prog.shape[0]) if prog[pc, 0] == Op.BRA
                  and (prog[pc, 6] or prog[pc, 7]))
    # join point is the BSYNC label (first instr both paths share): in this
    # program the not-taken path falls into 'join' and taken jumps to it.
    sync_pc = next(pc for pc in range(prog.shape[0])
                   if prog[pc, 0] == Op.BSYNC)
    assert ipd[bra_pc] == sync_pc


def test_call_ret():
    prog = assemble("""
    MOV R7, back            ; return address staged via MOV (SS V-D)
    CALL fn
back:
    MOV R2, 1
    EXIT
fn:
    MOV R3, 42
    RET R7
""")
    r = SIM.run(prog, CFG4)
    assert not r.deadlocked
    np.testing.assert_array_equal(r.regs[:, 2], [1, 1, 1, 1])
    np.testing.assert_array_equal(r.regs[:, 3], [42, 42, 42, 42])
