"""Golden-trace regression fixtures for the deterministic reference engines.

Every (program, mechanism) cell renders its full normalized event stream —
``begin`` meta, one ``issue`` line per scheduler slot, the ``end`` summary —
through :class:`~repro.engine.JsonlSink` and must match the checked-in
JSONL fixture token for token.  Any change to scheduling order, status
normalization, trace recording, or the sink wire format shows up as a
one-line diff here before it can silently shift the paper's numbers.

Regenerate intentionally with::

    pytest tests/test_goldens.py --regen-goldens
"""
import io
import json
import pathlib

import pytest

from repro.core import MachineConfig
from repro.core.programs import (diamond_program, fig5_program, fig6_program,
                                 warpsync_program)
from repro.engine import JsonlSink, Simulator

GOLDEN_DIR = pathlib.Path(__file__).parent / "goldens"
GOLDEN_CFG = MachineConfig(n_threads=4, max_steps=4096)

PROGRAMS = {
    "fig5": fig5_program,
    "fig6": fig6_program,
    "diamond": diamond_program,
    "warpsync": lambda: warpsync_program(4),
}
MECHANISMS = ("hanoi", "simt_stack")


def _render(prog_name: str, mechanism: str) -> str:
    buf = io.StringIO()
    Simulator(mechanism).run(PROGRAMS[prog_name](), GOLDEN_CFG,
                             sink=JsonlSink(buf), name=prog_name)
    return buf.getvalue()


@pytest.mark.parametrize("mechanism", MECHANISMS)
@pytest.mark.parametrize("prog_name", sorted(PROGRAMS))
def test_golden_trace(prog_name, mechanism, request):
    path = GOLDEN_DIR / f"{prog_name}__{mechanism}.jsonl"
    text = _render(prog_name, mechanism)
    if request.config.getoption("--regen-goldens"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(text, encoding="utf-8")
        pytest.skip(f"regenerated {path.name}")
    assert path.exists(), (
        f"missing golden fixture {path}; run pytest --regen-goldens")
    golden = path.read_text(encoding="utf-8")
    got, want = text.splitlines(), golden.splitlines()
    assert len(got) == len(want), (
        f"{path.name}: {len(got)} events vs golden {len(want)}")
    for i, (g, w) in enumerate(zip(got, want)):
        assert g == w, f"{path.name} line {i + 1}:\n  got:    {g}\n" \
                       f"  golden: {w}"


@pytest.mark.parametrize("prog_name", sorted(PROGRAMS))
def test_goldens_differ_between_mechanisms(prog_name):
    """The fixtures must actually pin *mechanism-specific* schedules: the
    paper's whole point is that the two machines issue differently (except
    the end-state summaries, which agree for these deadlock-free programs)."""
    a = [json.loads(ln) for ln in _render(prog_name, "hanoi").splitlines()]
    b = [json.loads(ln)
         for ln in _render(prog_name, "simt_stack").splitlines()]
    assert a[-1]["status"] == b[-1]["status"] == "ok"
    assert a[-1]["finished"] == b[-1]["finished"]
    issues_a = [(e["pc"], e["mask"]) for e in a if e["event"] == "issue"]
    issues_b = [(e["pc"], e["mask"]) for e in b if e["event"] == "issue"]
    assert issues_a != issues_b, (
        f"{prog_name}: hanoi and simt_stack issued identically — the "
        f"golden pair pins nothing")
