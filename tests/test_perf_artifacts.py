"""Gate on the SS Perf hillclimb artifact (results/perf.json): the headline
optimizations recorded there must show their claimed movement vs the baseline
sweep (results/dryrun.json).  Skipped when artifacts are absent."""
import json
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PERF = os.path.join(ROOT, "results", "perf.json")
BASE = os.path.join(ROOT, "results", "dryrun.json")

pytestmark = pytest.mark.skipif(
    not (os.path.exists(PERF) and os.path.exists(BASE)),
    reason="run repro.launch.dryrun --all and benchmarks.perf_iter first")


def _base(arch, shape):
    for r in json.load(open(BASE)):
        if (r["arch"], r["shape"], r["mesh"]) == (arch, shape, "single") \
                and r["status"] == "ok":
            return r
    raise KeyError((arch, shape))


def _variant(arch, shape, name):
    for r in json.load(open(PERF)):
        if (r["arch"], r["shape"], r.get("variant")) == (arch, shape, name):
            return r
    raise KeyError((arch, shape, name))


def test_hubert_prefill_chunked_fits():
    b = _base("hubert-xlarge", "prefill_32k")
    v = _variant("hubert-xlarge", "prefill_32k", "V1_chunked")
    assert v["memory"]["temp_bytes"] < 2 * 2**30
    assert v["memory"]["temp_bytes"] < b["memory"]["temp_bytes"] / 10


def test_internlm_train_collective_hillclimb():
    b = _base("internlm2-20b", "train_4k")
    v = _variant("internlm2-20b", "train_4k", "V5_zero1_chunked_mb8")
    assert v["roofline"]["collective_s"] < 0.65 * b["roofline"]["collective_s"]
    assert v["memory"]["temp_bytes"] < 15 * 2**30


def test_mixtral_prefill_chunked_skips_flops():
    """SWA EMPTY-band skipping must reduce COMPUTE, not just memory."""
    b = _base("mixtral-8x7b", "prefill_32k")
    v = _variant("mixtral-8x7b", "prefill_32k", "V1_chunked")
    assert v["roofline"]["compute_s"] < 0.8 * b["roofline"]["compute_s"]
    assert v["roofline"]["memory_s"] < 0.6 * b["roofline"]["memory_s"]


def test_rwkv_unroll_memory_hillclimb():
    b = _base("rwkv6-3b", "train_4k")
    v8 = _variant("rwkv6-3b", "train_4k", "V1_unroll8")
    v32 = _variant("rwkv6-3b", "train_4k", "V2_unroll32")
    assert v8["roofline"]["memory_s"] < 0.4 * b["roofline"]["memory_s"]
    assert v32["roofline"]["memory_s"] < 0.6 * v8["roofline"]["memory_s"]


def test_rwkv_chunked_matmul_headline():
    b = _base("rwkv6-3b", "train_4k")
    v = _variant("rwkv6-3b", "train_4k", "V3_chunked_matmul")
    assert v["roofline"]["memory_s"] < b["roofline"]["memory_s"] / 50
    assert v["memory"]["temp_bytes"] < 10 * 2**30
