"""Unified engine API: registry, batching, comparison, sinks, shims.

This is the contract of ``repro.engine`` — the canonical simulation entry
point: mechanism registry round-trips, ``run_batch`` == N x ``run``,
``compare()`` self-discrepancy is exactly 0.0, normalized out-of-fuel /
deadlock statuses agree across engines, trace sinks see the normalized
stream, and the ``repro.core`` deprecation shims still return the original
callables.
"""
import io
import json
import math
import warnings

import numpy as np
import pytest

from repro.core import MachineConfig
from repro.core.programs import (fig6_program, make_suite, spinlock_program)
from repro.engine import (JsonlSink, MemorySink, RingBufferSink, SimRequest,
                          SimStatus, Simulator, as_request,
                          available_mechanisms, classify_status,
                          get_mechanism, register_mechanism,
                          unregister_mechanism)

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
SUITE = make_suite(CFG, datasets=1)
SIM = Simulator("hanoi")


def _bench(name):
    return next(b for b in SUITE if b.name == name)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_mechanisms_registered():
    names = available_mechanisms()
    for expected in ("simt_stack", "hanoi", "hanoi_jax", "dualpath",
                     "turing_oracle", "volta_itps", "sm_interleave"):
        assert expected in names


def test_registry_round_trip():
    @register_mechanism("echo_test", backend="numpy",
                        description="registry round-trip probe")
    def _echo(req):
        return SIM.run(req, mechanism="hanoi")

    try:
        mech = get_mechanism("echo_test")
        assert mech.name == "echo_test"
        assert mech.description == "registry round-trip probe"
        assert "echo_test" in available_mechanisms()
        # registered mechanisms are first-class: usable through the façade
        r = Simulator("echo_test").run(_bench("DIAMOND"), CFG)
        assert r.status is SimStatus.OK
    finally:
        unregister_mechanism("echo_test")
    assert "echo_test" not in available_mechanisms()
    with pytest.raises(KeyError):
        get_mechanism("echo_test")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        register_mechanism("hanoi")(lambda req: None)


def test_unknown_mechanism_error_names_known_ones():
    with pytest.raises(KeyError, match="hanoi"):
        Simulator("no_such_mechanism")


# ---------------------------------------------------------------------------
# run / run_batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mech", ["hanoi", "simt_stack", "dualpath",
                                  "turing_oracle"])
def test_run_batch_equals_n_runs(mech):
    benches = [b for b in SUITE if b.name in ("HOTS0", "GAUS0", "RBFS0",
                                              "DIAMOND")]
    batch = SIM.run_batch(benches, CFG, mechanism=mech)
    singles = [SIM.run(b, CFG, mechanism=mech) for b in benches]
    assert len(batch) == len(singles)
    for a, b in zip(batch, singles):
        assert a.status == b.status
        assert a.trace == b.trace
        assert a.steps == b.steps
        np.testing.assert_array_equal(a.regs, b.regs)
        np.testing.assert_array_equal(a.mem, b.mem)


def test_jax_batch_matches_numpy_reference():
    """The vmap-batched JAX mechanism is bit-identical, per warp, to the
    numpy mechanism — through the public API only."""
    benches = [b for b in SUITE if b.name in ("HOTS0", "GAUS0", "FIG5",
                                              "DIAMOND")]
    jax_batch = SIM.run_batch(benches, CFG, mechanism="hanoi_jax")
    np_batch = SIM.run_batch(benches, CFG, mechanism="hanoi")
    for a, b in zip(jax_batch, np_batch):
        assert a.mechanism == "hanoi_jax" and b.mechanism == "hanoi"
        assert a.status == b.status
        assert a.trace == b.trace
        np.testing.assert_array_equal(a.regs, b.regs)
        np.testing.assert_array_equal(a.mem, b.mem)
        assert a.finished == b.finished


def test_empty_batch():
    assert SIM.run_batch([], CFG) == []


# ---------------------------------------------------------------------------
# normalized status
# ---------------------------------------------------------------------------

def test_status_ok():
    r = SIM.run(_bench("DIAMOND"), CFG)
    assert r.status is SimStatus.OK and r.ok and not r.deadlocked
    assert r.fuel_left > 0


def test_status_out_of_fuel_spinlock_prevolta():
    """The pre-Volta spinlock hang manifests as fuel exhaustion — flagged
    OUT_OF_FUEL, with the trace truncated at the last fueled slot."""
    cfg = MachineConfig(n_threads=4, max_steps=512)
    r = SIM.run(spinlock_program(), cfg, mechanism="simt_stack")
    assert r.status is SimStatus.OUT_OF_FUEL
    assert r.fuel_left == 0
    assert r.deadlocked                       # legacy view preserved
    assert len(r.trace) <= cfg.max_steps


def test_status_deadlock_structural():
    """Fig 6 without BREAK: BSYNC waits on a mask that can never assemble.
    Hanoi burns fuel spinning (OUT_OF_FUEL); what matters is that the
    status is not OK and fuel semantics are explicit."""
    from repro.core.programs import fig6_no_break_program
    cfg = MachineConfig(n_threads=4, max_steps=256)
    r = SIM.run(fig6_no_break_program(), cfg)
    assert r.status in (SimStatus.OUT_OF_FUEL, SimStatus.DEADLOCK)
    assert not r.ok


def test_fuel_override_on_request():
    r = SIM.run(_bench("DIAMOND"), CFG, fuel=3)
    assert r.status is SimStatus.OUT_OF_FUEL
    assert len(r.trace) == 3


def test_overrides_apply_to_existing_simrequest():
    """Passing a SimRequest plus cfg/kwargs must re-budget it, not silently
    ignore the overrides."""
    b = _bench("DIAMOND")
    req = SimRequest(program=b.program, cfg=CFG, init_mem=b.init_mem)
    r = SIM.run(req, fuel=3)
    assert r.status is SimStatus.OUT_OF_FUEL and len(r.trace) == 3
    small = CFG._replace(max_steps=4)
    r2 = SIM.run(req, small)
    assert r2.status is SimStatus.OUT_OF_FUEL and len(r2.trace) == 4
    assert as_request(req) is req          # no overrides -> pass-through


def test_classify_status_matrix():
    full = 0b1111
    assert classify_status(finished=full, full_mask=full, fuel_left=5,
                           error=None) is SimStatus.OK
    assert classify_status(finished=full, full_mask=full, fuel_left=0,
                           error=None) is SimStatus.OUT_OF_FUEL
    assert classify_status(finished=0b0011, full_mask=full, fuel_left=0,
                           error=None) is SimStatus.OUT_OF_FUEL
    assert classify_status(finished=0b0011, full_mask=full, fuel_left=9,
                           error=None) is SimStatus.DEADLOCK
    assert classify_status(finished=full, full_mask=full, fuel_left=5,
                           error="boom") is SimStatus.ERROR
    # fuel_left < 0 = "unknown" (legacy RunResult default): classify on the
    # finished mask alone, never OUT_OF_FUEL
    assert classify_status(finished=full, full_mask=full, fuel_left=-1,
                           error=None) is SimStatus.OK
    assert classify_status(finished=0b0011, full_mask=full, fuel_left=-1,
                           error=None) is SimStatus.DEADLOCK


def test_fuel_exhaustion_trace_equivalence_numpy_vs_jax():
    """Non-hypothesis regression for the out-of-fuel normalization: fuel
    dies mid-split on a divergent benchmark and both engines must agree on
    the truncated trace and the flag."""
    bench = _bench("RBFS0")
    for fuel in (5, 17, 41):
        a = SIM.run(bench, CFG, fuel=fuel, mechanism="hanoi")
        b = SIM.run(bench, CFG, fuel=fuel, mechanism="hanoi_jax")
        assert a.status is SimStatus.OUT_OF_FUEL
        assert b.status is SimStatus.OUT_OF_FUEL
        assert a.trace == b.trace
        assert a.steps == b.steps
        assert a.fuel_left == b.fuel_left == 0


# ---------------------------------------------------------------------------
# compare
# ---------------------------------------------------------------------------

def test_compare_self_discrepancy_zero():
    benches = [b for b in SUITE if b.name in ("HOTS0", "RBFS0", "DIAMOND")]
    report = SIM.compare(["hanoi", "hanoi_jax"], benches, CFG)
    for row in report.rows:
        assert row.discrepancy == 0.0
        assert row.ipc_delta == 0.0
        assert row.util_a == row.util_b


def test_compare_oracle_skip_diverges_on_bfsd():
    report = SIM.compare(["hanoi", "turing_oracle"], SUITE, CFG,
                         pairs=[("hanoi", "turing_oracle")])
    rows = {r.program: r for r in report.rows}
    assert rows["BFSD"].discrepancy > 0            # the skipped BSYNC shows
    assert rows["DIAMOND"].discrepancy == 0.0      # no skip pcs -> identical


def test_compare_without_timing_model():
    bench = _bench("BFSD")
    rep = SIM.compare(["hanoi", "turing_oracle"], [bench], CFG,
                      pairs=[("hanoi", "turing_oracle")], timing=False)
    row = rep.rows[0]
    assert math.isnan(row.ipc_a) and math.isnan(row.ipc_delta)
    assert row.discrepancy > 0
    # utilization falls back to the trace-derived value
    a = SIM.run(bench, CFG)
    b = SIM.run(bench, CFG, mechanism="turing_oracle")
    assert row.util_a == a.utilization and row.util_b == b.utilization


def test_compare_anonymous_programs_get_unique_ids():
    prog = _bench("DIAMOND").program
    report = SIM.compare(["hanoi", "simt_stack"], [prog, prog], CFG)
    assert {r.program for r in report.rows} == {"prog0", "prog1"}


# ---------------------------------------------------------------------------
# trace sinks
# ---------------------------------------------------------------------------

def test_memory_sink_sees_normalized_stream():
    sink = MemorySink()
    r = SIM.run(_bench("DIAMOND"), CFG, sink=sink)
    assert len(sink.runs) == 1
    run = sink.runs[0]
    assert run["meta"]["mechanism"] == "hanoi"
    assert run["meta"]["program"] == "DIAMOND"
    assert run["trace"] == list(r.trace)
    assert run["result"] is r


def test_jsonl_sink_round_trip():
    buf = io.StringIO()
    r = SIM.run(_bench("DIAMOND"), CFG, sink=JsonlSink(buf))
    events = [json.loads(line) for line in buf.getvalue().splitlines()]
    assert events[0]["event"] == "begin"
    assert events[-1]["event"] == "end"
    issues = [e for e in events if e["event"] == "issue"]
    assert [(e["pc"], e["mask"]) for e in issues] == list(r.trace)
    assert events[-1]["status"] == "ok"


def test_ring_buffer_sink_keeps_tail():
    sink = RingBufferSink(capacity=8)
    r = SIM.run(_bench("HOTS0"), CFG, sink=sink)
    assert sink.total_emitted == len(r.trace) > 8
    assert sink.snapshot() == list(r.trace)[-8:]
    assert sink.last_result is r


def test_sink_attached_at_construction_sees_batches():
    sink = MemorySink()
    sim = Simulator("hanoi", sink=sink)
    benches = [b for b in SUITE if b.name in ("HOTS0", "DIAMOND")]
    sim.run_batch(benches, CFG)
    assert [run["meta"]["program"] for run in sink.runs] == \
        ["HOTS0", "DIAMOND"]


# ---------------------------------------------------------------------------
# meta immutability (frozen dataclasses must not leak shared-mutable state)
# ---------------------------------------------------------------------------

def test_result_meta_is_immutable_and_unshared():
    """``field(default_factory=dict)`` on a frozen dataclass still hands out
    a caller-mutable dict; the normalized MappingProxyType must reject
    writes on both the default and an explicitly provided mapping."""
    a = SIM.run(_bench("DIAMOND"), CFG)
    b = SIM.run(_bench("DIAMOND"), CFG)
    with pytest.raises(TypeError):
        a.meta["x"] = 1                          # default meta: read-only
    assert a.meta is not b.meta

    src = {"k": 1}
    req = SimRequest(program=_bench("DIAMOND").program, cfg=CFG, meta=src)
    with pytest.raises(TypeError):
        req.meta["k"] = 2                        # explicit meta: read-only
    src["k"] = 99                                # and detached from the
    assert req.meta["k"] == 1                    # caller's dict


def test_request_meta_reaches_mechanisms():
    """meta options flow through run/as_request to the mechanism: a tiny
    itps patience forces the fair scheduler far more often, changing the
    volta schedule (but never the architectural results)."""
    b = _bench("RBFS0")
    default = SIM.run(b, CFG, mechanism="volta_itps")
    fair = SIM.run(b, CFG, mechanism="volta_itps", meta={"itps_patience": 1})
    assert default.ok and fair.ok
    assert default.trace != fair.trace
    np.testing.assert_array_equal(default.mem, fair.mem)


# ---------------------------------------------------------------------------
# request coercion + deprecation shims
# ---------------------------------------------------------------------------

def test_as_request_coercions():
    b = _bench("BFSD")
    req = as_request(b, CFG)
    assert req.name == "BFSD"
    assert req.bsync_skip_pcs == tuple(b.skip_bsync_pcs)
    raw = as_request(b.program, CFG)
    assert raw.name == "" and raw.bsync_skip_pcs == ()
    assert as_request(req) is req
    # overrides that collide with Benchmark-derived fields must win, not
    # raise "multiple values for keyword argument"
    other_mem = np.ones(CFG.mem_size, np.int32)
    over = as_request(b, CFG, init_mem=other_mem, name="custom")
    assert over.name == "custom"
    np.testing.assert_array_equal(over.init_mem, other_mem)
    r = SIM.run(b, CFG, init_mem=other_mem)
    assert r.status is SimStatus.OK


def test_report_pair_unknown_raises():
    report = SIM.compare(["hanoi", "turing_oracle"], [_bench("DIAMOND")],
                         CFG, pairs=[("hanoi", "turing_oracle")])
    with pytest.raises(KeyError, match="computed pairs"):
        report.pair("turing_oracle", "hanoi")      # swapped order
    with pytest.raises(KeyError):
        report.mean_discrepancy("hanoi", "nope")


def test_core_shims_warn_and_return_identical_callables():
    import repro.core
    import repro.core.interp
    import repro.core.dualpath
    for name, target in [
            ("run_hanoi", repro.core.interp.run_hanoi),
            ("run_simt_stack", repro.core.interp.run_simt_stack),
            ("run_dual_path", repro.core.dualpath.run_dual_path)]:
        with pytest.warns(DeprecationWarning, match="repro.engine"):
            fn = getattr(repro.core, name)
        assert fn is target


def test_shimmed_entry_point_returns_identical_results():
    import repro.core
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = repro.core.run_hanoi
    b = _bench("DIAMOND")
    old = legacy(b.program, CFG, init_mem=b.init_mem)
    new = SIM.run(b, CFG)
    assert old.trace == list(new.trace)
    np.testing.assert_array_equal(old.regs, new.regs)
    assert old.finished == new.finished
    assert old.fuel_left == new.fuel_left
