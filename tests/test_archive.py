"""Archive read/replay: the offline half of the durable archival path.

Acceptance contract (ISSUE 4):

* a service-written archive (>= 2 rotations, one degraded/truncated tail)
  reads back run-for-run through ``ArchiveReader`` — the truncated tail is
  *reported*, never raised;
* replaying the archive reproduces the live ``Simulator.compare``
  discrepancy numbers **bit-equal per run**, for every mechanism in
  ``iter_mechanisms()`` (self-replay is exactly 0.0);
* the Myers bit-parallel ``levenshtein`` equals the classic DP exactly
  (seeded-random differential here; the hypothesis property lives in
  ``test_property_core``).
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.archive import (ArchiveIndex, ArchiveReader, Replayer,
                           ReplayReport, compact, nearest_rank,
                           request_from_meta)
from repro.archive.replay import Aggregate
from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.core.trace import levenshtein, levenshtein_dp, trace_tokens
from repro.engine import (RotatingJsonlSink, SimRequest, Simulator,
                          as_request, feed_result, iter_mechanisms, run_meta)
from repro.service import SimulationService

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
SUITE = make_suite(CFG, datasets=1)
SIM = Simulator("hanoi")
# deadlock-free on every registered mechanism; BFSD carries bsync_skip_pcs
# so the turing_oracle rows are non-trivial
BENCH_NAMES = ("HOTS0", "DIAMOND", "BFSD")


def _bench(name):
    return next(b for b in SUITE if b.name == name)


def _write_archive(tmp_path, mechanisms, *, max_bytes=4096, names=BENCH_NAMES,
                   workers=1):
    """Serve every (bench, mechanism) pair into a rotating archive."""
    sink = RotatingJsonlSink(str(tmp_path), max_bytes=max_bytes)
    with SimulationService(default_mechanism="hanoi", max_batch=4,
                           max_wait_s=0.01, workers=workers,
                           archive=sink) as svc:
        tickets = [svc.submit(_bench(n), CFG, mechanism=m)
                   for m in mechanisms for n in names]
        svc.flush()
        results = [t.result() for t in tickets]
    sink.flush()
    sink.close()
    assert all(r.error is None for r in results)
    return sink


# ---------------------------------------------------------------------------
# Myers levenshtein == DP (differential; hypothesis property in
# test_property_core)
# ---------------------------------------------------------------------------

def test_levenshtein_myers_equals_dp_seeded():
    rng = np.random.default_rng(1234)
    for _ in range(400):
        n, m = rng.integers(0, 48, size=2)
        alpha = int(rng.integers(1, 8))
        a = rng.integers(0, alpha, size=n)
        b = rng.integers(0, alpha, size=m)
        assert levenshtein(a, b) == levenshtein_dp(a, b)


def test_levenshtein_edges():
    assert levenshtein([], []) == 0
    assert levenshtein([], [1, 2]) == 2
    assert levenshtein([1, 2, 3], []) == 3
    assert levenshtein([1, 2, 3], [1, 2, 3]) == 0
    assert levenshtein([1, 2, 3], [4, 5, 6]) == 3
    assert levenshtein([1], [1, 2, 3, 4]) == 3
    # asymmetric lengths exercise the pattern/text swap
    rng = np.random.default_rng(7)
    a = rng.integers(0, 5, size=300)
    b = rng.integers(0, 5, size=20)
    assert levenshtein(a, b) == levenshtein_dp(a, b)
    assert levenshtein(a, b) == levenshtein(b, a)


def test_levenshtein_on_real_traces():
    ra = SIM.run(_bench("BFSD"), CFG)
    rb = SIM.run(_bench("BFSD"), CFG, mechanism="turing_oracle")
    ta, tb = trace_tokens(list(ra.trace)), trace_tokens(list(rb.trace))
    assert levenshtein(ta, tb) == levenshtein_dp(ta, tb) > 0
    assert levenshtein(ta, ta) == 0


# ---------------------------------------------------------------------------
# reader: rotation, reassembly, meta normalization
# ---------------------------------------------------------------------------

def test_reader_reassembles_rotated_archive(tmp_path):
    sink = _write_archive(tmp_path, ["hanoi"])
    assert len(sink.paths) >= 2                     # forced >= 2 rotations
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert reader.report.clean
    assert len(runs) == sink.runs_written == len(BENCH_NAMES)
    by_prog = {r.program: r for r in runs}
    assert set(by_prog) == set(BENCH_NAMES)
    for name in BENCH_NAMES:
        run = by_prog[name]
        live = SIM.run(_bench(name), CFG)
        assert run.trace == live.trace              # tuples, not JSON lists
        assert isinstance(run.trace, tuple)
        assert run.status == live.status.value
        assert run.steps == live.steps
        assert run.fuel_left == live.fuel_left
        assert run.mechanism == "hanoi"
        assert run.replayable


def test_request_round_trips_through_meta():
    req = as_request(_bench("BFSD"), CFG, fuel=4096,
                     majority_first=False,
                     meta={"itps_patience": 3, "tags": [1, 2]})
    meta = run_meta("hanoi", req)
    back = request_from_meta(json.loads(json.dumps(meta)))  # via JSON
    assert back is not None
    np.testing.assert_array_equal(back.program, req.program)
    np.testing.assert_array_equal(back.init_mem, req.init_mem)
    assert back.cfg == req.cfg
    assert back.fuel == 4096 and back.majority_first is False
    assert back.bsync_skip_pcs == req.bsync_skip_pcs != ()
    assert back.meta["itps_patience"] == 3
    assert back.meta["tags"] == (1, 2)              # JSON list -> tuple
    assert back.name == req.name


def test_request_from_meta_without_payload_is_none():
    assert request_from_meta({"mechanism": "hanoi", "program": "x"}) is None
    assert request_from_meta({"replay": {"cfg": {}}}) is None   # undecodable


# ---------------------------------------------------------------------------
# degradation: truncated tail is reported, never raised
# ---------------------------------------------------------------------------

def test_reader_tolerates_truncated_tail_line(tmp_path):
    sink = _write_archive(tmp_path, ["hanoi"])
    last = sink.paths[-1]
    raw = open(last, encoding="utf-8").read()
    # chop the trailing newline plus half the final event: a writer killed
    # mid-write
    open(last, "w", encoding="utf-8").write(raw[:-max(10, len(raw) // 50)])
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()                             # does not raise
    assert reader.report.truncated_tail == last
    assert reader.report.truncated_runs == 1
    assert len(runs) == sink.runs_written - 1
    # the surviving runs replay clean
    report = Replayer().replay(runs)
    assert report.replayed == len(runs)
    assert report.mean_discrepancy() == 0.0


def test_reader_tolerates_file_ending_mid_run(tmp_path):
    sink = _write_archive(tmp_path, ["hanoi"])
    last = sink.paths[-1]
    lines = open(last, encoding="utf-8").read().splitlines(keepends=True)
    # drop the end event but keep whole lines: node died between lines
    open(last, "w", encoding="utf-8").writelines(lines[:-1])
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert reader.report.truncated_tail == last
    assert reader.report.truncated_runs == 1
    assert len(runs) == sink.runs_written - 1


def test_reader_counts_mid_archive_corruption(tmp_path):
    sink = _write_archive(tmp_path, ["hanoi"])
    first = sink.paths[0]
    lines = open(first, encoding="utf-8").read().splitlines(keepends=True)
    lines[1] = "{not json}\n"                        # corrupt one issue line
    open(first, "w", encoding="utf-8").writelines(lines)
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert reader.report.corrupt_lines == 1
    assert reader.report.interrupted_runs == 1       # that run is discarded
    assert len(runs) == sink.runs_written - 1
    assert not reader.report.clean


def test_reader_missing_directory_raises():
    with pytest.raises(FileNotFoundError):
        ArchiveReader("/nonexistent/archive/dir")


# ---------------------------------------------------------------------------
# the acceptance round trip: service -> archive -> replay == live compare,
# for every registered mechanism
# ---------------------------------------------------------------------------

def test_round_trip_replay_matches_live_compare_every_mechanism(tmp_path):
    mechanisms = [m.name for m in iter_mechanisms()]
    sink = _write_archive(tmp_path, mechanisms, max_bytes=8192)
    assert len(sink.paths) >= 2                      # >= 2 rotations

    # degrade the tail: lop off half of the final line (crashed writer)
    last = sink.paths[-1]
    raw = open(last, encoding="utf-8").read()
    open(last, "w", encoding="utf-8").write(raw[:-20])

    reader = ArchiveReader(str(tmp_path))

    # 1) self-replay: every surviving run is bit-equal (0.0 discrepancy)
    self_report = Replayer().replay(reader)
    assert reader.report.truncated_runs == 1
    expected_rows = len(mechanisms) * len(BENCH_NAMES) - 1
    assert self_report.replayed == expected_rows
    assert all(r.discrepancy == 0.0 for r in self_report.rows)
    assert all(r.replayed_status == r.archived_status
               for r in self_report.rows)

    # 2) cross-replay under one mechanism == live Simulator.compare,
    #    bit-equal per run (the offline Fig 9)
    progs = [_bench(n) for n in BENCH_NAMES]
    live = SIM.compare(["hanoi"] + [m for m in mechanisms if m != "hanoi"],
                       progs, CFG, timing=False,
                       pairs=[("hanoi", m) for m in mechanisms])
    expect = {(row.program, row.mech_b): row.discrepancy
              for row in live.rows}
    cross = Replayer("hanoi").replay(reader)
    assert cross.replayed == expected_rows
    for row in cross.rows:
        key = (row.program, row.archived_mechanism)
        assert row.discrepancy == expect[key], (key, row)
    # per-pair breakdown covers every archived mechanism
    assert {r.archived_mechanism for r in cross.rows} == set(mechanisms)


def test_replay_through_running_service(tmp_path):
    _write_archive(tmp_path, ["hanoi", "simt_stack"])
    sim_report = Replayer().replay(str(tmp_path))
    with SimulationService(default_mechanism="hanoi", max_batch=4,
                           workers=2) as svc:
        svc_report = Replayer(service=svc).replay(str(tmp_path))
    assert svc_report.replayed == sim_report.replayed > 0
    assert [r.discrepancy for r in svc_report.rows] == \
        [r.discrepancy for r in sim_report.rows]
    assert svc_report.mean_discrepancy() == 0.0


# ---------------------------------------------------------------------------
# replayability accounting
# ---------------------------------------------------------------------------

def test_unreplayable_and_untraced_runs_are_counted(tmp_path):
    sink = RotatingJsonlSink(str(tmp_path))
    res = SIM.run(_bench("DIAMOND"), CFG)
    # 1) replayable + traced
    feed_result(sink, res, run_meta("hanoi", as_request(_bench("DIAMOND"),
                                                        CFG)))
    # 2) hand-built meta (the SM-cell warp shape): readable, not replayable
    feed_result(sink, res, {"mechanism": "hanoi", "program": "sm/w0"})
    # 3) replayable but archived without a trace
    req = as_request(_bench("DIAMOND"), CFG, record_trace=False)
    feed_result(sink, SIM.run(req), run_meta("hanoi", req))
    sink.flush()
    sink.close()
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert len(runs) == 3
    assert [r.replayable for r in runs] == [True, False, True]
    report = Replayer().replay(runs)
    assert report.replayed == 1
    assert report.skipped_unreplayable == 1
    assert report.skipped_untraced == 1
    assert report.read is None                       # pre-read runs
    assert report.rows[0].discrepancy == 0.0


def test_sm_cell_archives_are_replayable(tmp_path):
    """ISSUE 5 tentpole: service-archived SM-cell warps carry the full
    replay payload + cell coordinates (sm_run_meta) — the PR 4 read path
    used to see them as hand-built, unreplayable meta."""
    sink = RotatingJsonlSink(str(tmp_path))
    with SimulationService(default_mechanism="hanoi", workers=1,
                           archive=sink) as svc:
        sm = svc.submit_sm(_bench("DIAMOND"), CFG, n_warps=3,
                           inner="hanoi").result()
    sink.flush()
    sink.close()
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert len(runs) == sm.n_warps == 3
    assert all(r.replayable for r in runs)
    assert all(r.meta["sm_policy"] == "round_robin" for r in runs)
    assert [r.meta["sm_warp"] for r in runs] == [0, 1, 2]
    assert len({r.sm_cell for r in runs}) == 1       # one cell id
    # archived warp == the warp's SimResult from the live cell, bit-equal
    for run, warp in zip(runs, sm.warps):
        assert run.trace == warp.trace
        assert run.status == warp.status.value
    report = Replayer().replay(reader)
    assert report.replayed == 3
    assert report.skipped_unreplayable == 0
    assert all(r.discrepancy == 0.0 for r in report.rows)


# ---------------------------------------------------------------------------
# report aggregation + CLI
# ---------------------------------------------------------------------------

def test_nearest_rank_and_aggregate():
    assert nearest_rank([1.0, 2.0], 0.5) == 1.0      # NOT the max
    assert nearest_rank([1.0, 2.0], 0.99) == 2.0
    assert np.isnan(nearest_rank([], 0.5))
    vals = [float(i) for i in range(1, 1001)]
    assert nearest_rank(vals, 0.5) == 500.0          # index 499, not 500
    agg = Aggregate.of([0.0, 0.1, 0.2, 0.3])
    assert agg.count == 4 and agg.p50 == 0.1 and agg.max == 0.3
    assert agg.mean == pytest.approx(0.15)


def test_report_breakdowns_and_render(tmp_path):
    _write_archive(tmp_path, ["hanoi", "turing_oracle"])
    report = Replayer("hanoi").replay(str(tmp_path))
    pairs = report.by_mechanism()
    assert set(pairs) == {"hanoi vs hanoi", "hanoi vs turing_oracle"}
    assert pairs["hanoi vs hanoi"].mean == 0.0
    # BFSD's skipped BSYNCs make the oracle's archived trace diverge
    assert pairs["hanoi vs turing_oracle"].max > 0.0
    progs = report.by_program()
    assert set(progs) == set(BENCH_NAMES)
    text = report.render()
    assert "overall:" in text and "by mechanism pair:" in text
    assert "hanoi vs turing_oracle" in text


def test_cli_expect_zero(tmp_path, capsys):
    from repro.archive.__main__ import main
    _write_archive(tmp_path, ["hanoi"])
    assert main([str(tmp_path), "--expect-zero"]) == 0
    out = capsys.readouterr().out
    assert "[replay] overall:" in out
    # cross-mechanism replay is NOT zero on BFSD -> the gate trips
    assert main([str(tmp_path), "--mechanism", "turing_oracle",
                 "--expect-zero"]) == 1
    # empty replay set trips it too
    empty = tmp_path / "empty"
    empty.mkdir()
    assert main([str(empty), "--expect-zero"]) == 1


def test_cli_limit(tmp_path, capsys):
    from repro.archive.__main__ import main
    _write_archive(tmp_path, ["hanoi"])
    assert main([str(tmp_path), "--limit", "1"]) == 0
    assert "[replay] 1 run(s) replayed" in capsys.readouterr().out


def test_unknown_archived_mechanism_is_skipped_not_fatal(tmp_path):
    """A plugin archive replayed in a process without the plugin must not
    kill the fleet job — the foreign runs are counted, the rest replay."""
    from repro.engine import register_mechanism, unregister_mechanism

    @register_mechanism("tmp_plugin_mech", description="test-only")
    def _runner(req):
        return SIM.run(req)                      # delegate to hanoi

    try:
        sink = _write_archive(tmp_path, ["hanoi", "tmp_plugin_mech"])
    finally:
        unregister_mechanism("tmp_plugin_mech")
    assert sink.runs_written == 2 * len(BENCH_NAMES)
    report = Replayer().replay(str(tmp_path))    # plugin no longer exists
    assert report.skipped_unknown_mechanism == len(BENCH_NAMES)
    assert report.replayed == len(BENCH_NAMES)   # hanoi runs still replay
    assert report.mean_discrepancy() == 0.0
    assert "unknown-mechanism" in report.render()


def test_corrupt_complete_tail_line_is_corruption_not_truncation(tmp_path):
    """truncated_tail fingerprints a crashed writer (partial final line);
    a newline-terminated line that fails to parse is data corruption."""
    sink = _write_archive(tmp_path, ["hanoi"])
    last = sink.paths[-1]
    lines = open(last, encoding="utf-8").read().splitlines(keepends=True)
    lines[-1] = "{bit rot}\n"                      # complete but undecodable
    open(last, "w", encoding="utf-8").writelines(lines)
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert reader.report.truncated_tail is None
    assert reader.report.corrupt_lines == 1
    assert reader.report.interrupted_runs == 1     # that run lost its end
    assert len(runs) == sink.runs_written - 1


def test_meta_dropped_payload_is_unreplayable():
    """A payload whose writer dropped meta entries must not replay as if
    faithful — the missing mechanism options could change execution."""
    req = as_request(_bench("DIAMOND"), CFG, meta={"opaque": object()})
    meta = run_meta("hanoi", req)
    assert meta["replay"]["meta_dropped"] == ["opaque"]
    assert request_from_meta(json.loads(json.dumps(meta))) is None
    # and the Replayer counts it as unreplayable instead of diffing it
    res = SIM.run(req)
    report = Replayer().replay([_as_archived(meta, res)])
    assert report.replayed == 0 and report.skipped_unreplayable == 1


def test_numpy_meta_values_survive_payload():
    req = as_request(_bench("DIAMOND"), CFG,
                     meta={"flag": np.bool_(True), "n": np.int64(3)})
    meta = run_meta("hanoi", req)
    assert "meta_dropped" not in meta["replay"]
    back = request_from_meta(json.loads(json.dumps(meta)))
    assert back is not None
    assert back.meta["flag"] is True
    assert back.meta["n"] == 3


def _as_archived(meta, res):
    """Wrap a (meta, result) pair as an ArchivedRun for replayer tests."""
    from repro.archive import ArchivedRun
    return ArchivedRun(meta=meta, trace=tuple(res.trace),
                       mechanism=res.mechanism, status=res.status.value,
                       steps=res.steps, fuel_left=res.fuel_left,
                       finished=int(res.finished),
                       utilization=res.utilization, error=res.error,
                       path="<memory>", line=1)


# ---------------------------------------------------------------------------
# ISSUE 5 tentpole acceptance: service-archived SM cells over a rotated
# archive — >= 2 policies, heterogeneous per-warp programs, every
# single-warp mechanism — replay to exactly 0.0 and group back into cells
# ---------------------------------------------------------------------------

def _write_sm_grid_archive(tmp_path, inners, policies, *, max_bytes=4096):
    progs = [_bench(n) for n in BENCH_NAMES]         # heterogeneous warps
    sink = RotatingJsonlSink(str(tmp_path), max_bytes=max_bytes)
    cells = [dict(programs=progs, cfg=CFG, inner=m, policy=p)
             for m in inners for p in policies]
    with SimulationService(default_mechanism="hanoi", workers=2,
                           archive=sink) as svc:
        grid = svc.run_sm_grid(cells, timeout=600)
    sink.flush()
    sink.close()
    return sink, cells, grid


def test_sm_round_trip_every_mechanism(tmp_path):
    # every single-warp mechanism: composite SM engines (sm_interleave,
    # sm_jax) cannot nest as an inner
    inners = [m.name for m in iter_mechanisms() if "composite" not in m.tags]
    policies = ("round_robin", "greedy_then_oldest")
    sink, cells, grid = _write_sm_grid_archive(tmp_path, inners, policies)
    assert len(sink.paths) >= 2                      # rotated archive
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert len(runs) == sink.runs_written == len(cells) * len(BENCH_NAMES)
    assert all(r.replayable for r in runs)

    report = Replayer().replay(reader)
    assert report.replayed == len(runs)
    assert all(r.discrepancy == 0.0 for r in report.rows)   # self-replay
    assert all(r.replayed_status == r.archived_status for r in report.rows)
    # warps group back into their cells and policies
    by_cell = report.by_sm_cell()
    assert len(by_cell) == len(cells)
    assert all(agg.count == len(BENCH_NAMES) for agg in by_cell.values())
    by_policy = report.by_sm_policy()
    assert set(by_policy) == set(policies)
    assert all(agg.count == len(inners) * len(BENCH_NAMES)
               for agg in by_policy.values())
    assert "by SM cell:" in report.render()
    # every inner mechanism's warps made it into the archive
    assert {r.meta["mechanism"] for r in runs} == set(inners)

    # bit-equality with live execution: the archived warp trace equals a
    # fresh standalone run of the reconstructed request (what
    # Simulator.compare would diff against)
    for run in runs:
        if run.meta["mechanism"] in ("hanoi", "turing_oracle"):
            live = SIM.run(run.request(), mechanism=run.meta["mechanism"])
            assert run.trace == live.trace


def test_facade_run_sm_sink_matches_service_archive(tmp_path):
    """Simulator.run_sm with a sink stamps the same SM variant meta the
    service path writes — one builder, no fork."""
    sink = RotatingJsonlSink(str(tmp_path))
    sm = Simulator("hanoi", sink=sink).run_sm(
        [_bench("DIAMOND"), _bench("HOTS0")], CFG, inner="hanoi",
        policy="greedy_then_oldest")
    sink.flush()
    sink.close()
    runs = ArchiveReader(str(tmp_path)).runs()
    assert len(runs) == sm.n_warps == 2
    assert len(sm.requests) == 2                     # requests kept on SmResult
    for w, run in enumerate(runs):
        assert run.replayable
        assert run.meta["sm_warp"] == w
        assert run.meta["sm_warps"] == 2
        assert run.meta["sm_policy"] == "greedy_then_oldest"
        assert run.trace == sm.warps[w].trace
    report = Replayer().replay(runs)
    assert report.replayed == 2
    assert report.mean_discrepancy() == 0.0


# ---------------------------------------------------------------------------
# sidecar index: O(1) get, rebuild-on-mismatch, compaction
# ---------------------------------------------------------------------------

def test_index_get_bit_equal_to_sequential(tmp_path):
    sink = _write_archive(tmp_path, ["hanoi", "simt_stack"])
    reader = ArchiveReader(str(tmp_path))
    seq = reader.runs()
    idx = ArchiveIndex.build(str(tmp_path))
    assert os.path.exists(idx.path)
    assert len(idx) == len(seq) == sink.runs_written
    for entry, run in zip(idx.entries, seq):
        got = reader.get(entry.run_id)
        assert dict(got.meta) == dict(run.meta)      # bit-equal runs
        assert got.trace == run.trace
        assert (got.mechanism, got.status, got.steps, got.fuel_left) == \
            (run.mechanism, run.status, run.steps, run.fuel_left)
        assert entry.program == run.program
        assert entry.mechanism == run.meta["mechanism"]
    with pytest.raises(KeyError, match="unknown run id"):
        reader.get("run-999999")


def test_index_loads_without_rescan_and_rebuilds_on_mismatch(tmp_path):
    _write_archive(tmp_path, ["hanoi"])
    built = ArchiveIndex.build(str(tmp_path))
    loaded = ArchiveIndex.load(str(tmp_path))
    assert loaded is not None and loaded.fresh()
    assert loaded.entries == built.entries
    assert ArchiveIndex.ensure(str(tmp_path)).entries == built.entries

    # grow the archive behind the index's back: a new rotated file
    from repro.engine import JsonlSink
    res = SIM.run(_bench("DIAMOND"), CFG)
    extra = JsonlSink(str(tmp_path / "traces-00099.jsonl"))
    feed_result(extra, res, run_meta("hanoi", as_request(_bench("DIAMOND"),
                                                         CFG)))
    extra.close()
    assert not loaded.fresh()                        # fingerprint mismatch
    reader = ArchiveReader(str(tmp_path))
    rebuilt_id = f"run-{len(built.entries):06d}"
    got = reader.get(rebuilt_id)                     # transparent rebuild
    assert got.program == "DIAMOND"
    assert reader._index is not None and reader._index.fresh()

    # a corrupt sidecar is treated as missing, never fatal
    with open(ArchiveIndex.ensure(str(tmp_path)).path, "w") as fh:
        fh.write("not an index\n")
    assert ArchiveIndex.load(str(tmp_path)) is None
    assert len(ArchiveIndex.ensure(str(tmp_path))) == len(built.entries) + 1


def test_compact_drops_debris_preserves_runs_bit_equal(tmp_path):
    sink = _write_archive(tmp_path, ["hanoi", "simt_stack"])
    # damage: corrupt one mid-archive issue line + truncate the tail
    first, last = sink.paths[0], sink.paths[-1]
    lines = open(first, encoding="utf-8").read().splitlines(keepends=True)
    lines[1] = "{not json}\n"
    open(first, "w", encoding="utf-8").writelines(lines)
    raw = open(last, encoding="utf-8").read()
    open(last, "w", encoding="utf-8").write(raw[:-20])

    reader = ArchiveReader(str(tmp_path))
    before = reader.runs()
    assert not reader.report.clean
    assert len(before) == sink.runs_written - 2      # two runs damaged

    report = compact(str(tmp_path))
    assert report.runs_kept == len(before)
    assert report.bytes_dropped > 0
    after_reader = ArchiveReader(str(tmp_path))
    after = after_reader.runs()
    assert after_reader.report.clean                 # debris gone
    assert len(after) == len(before)
    for a, b in zip(after, before):                  # byte-for-byte fidelity
        assert dict(a.meta) == dict(b.meta)
        assert a.trace == b.trace and a.status == b.status

    # the index was rebuilt by compaction: get() is bit-equal again
    idx = ArchiveIndex.load(str(tmp_path))
    assert idx is not None and idx.fresh() and len(idx) == len(after)
    got = after_reader.get(idx.entries[-1].run_id)
    assert got.trace == after[-1].trace
    # self-replay still exact over the compacted archive
    assert Replayer().replay(after_reader).mean_discrepancy() == 0.0


# ---------------------------------------------------------------------------
# partial walks: ReadReport.complete + the --expect-zero gate
# ---------------------------------------------------------------------------

def test_partial_walk_is_flagged_incomplete(tmp_path):
    sink = _write_archive(tmp_path, ["hanoi"])
    reader = ArchiveReader(str(tmp_path))
    reader.runs()
    assert reader.report.complete                    # full walk
    reader.runs(limit=1)
    assert not reader.report.complete                # broke mid-iteration
    assert reader.report.clean                       # ...which is why clean
    # alone must not be trusted: damage the unscanned tail and a limited
    # walk still reports clean
    raw = open(sink.paths[-1], encoding="utf-8").read()
    open(sink.paths[-1], "w", encoding="utf-8").write(raw[:-20])
    reader.runs(limit=1)
    assert reader.report.clean and not reader.report.complete
    reader.runs()
    assert not reader.report.clean                   # the full walk sees it


def test_cli_expect_zero_refuses_partial_walk(tmp_path, capsys):
    from repro.archive.__main__ import main
    _write_archive(tmp_path, ["hanoi"])
    assert main([str(tmp_path), "--expect-zero"]) == 0
    assert main([str(tmp_path), "--limit", "1", "--expect-zero"]) == 1
    assert "partial walk" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# CLI subcommands: index / get / compact
# ---------------------------------------------------------------------------

def test_cli_index_get_compact(tmp_path, capsys):
    from repro.archive.__main__ import main
    _write_archive(tmp_path, ["hanoi"])
    assert main(["index", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert f"{len(BENCH_NAMES)} run(s)" in out and "run-000000" in out

    assert main(["get", str(tmp_path), "run-000000"]) == 0
    out = capsys.readouterr().out
    assert "replayable=True" in out and "mechanism=hanoi" in out

    assert main(["get", str(tmp_path), "run-000000", "--json"]) == 0
    obj = json.loads(capsys.readouterr().out)
    assert obj["id"] == "run-000000" and obj["status"] == "ok"
    assert obj["trace"] and "replay" in obj["meta"]

    assert main(["get", str(tmp_path), "run-4242"]) == 1
    assert "unknown run id" in capsys.readouterr().err

    assert main(["compact", str(tmp_path)]) == 0
    assert "kept" in capsys.readouterr().out
    assert main([str(tmp_path), "--expect-zero"]) == 0   # still replays clean


# ---------------------------------------------------------------------------
# --watch: streaming replay of a growing archive
# ---------------------------------------------------------------------------

def test_watch_picks_up_appended_runs(tmp_path):
    res = SIM.run(_bench("DIAMOND"), CFG)
    meta = run_meta("hanoi", as_request(_bench("DIAMOND"), CFG))
    sink = RotatingJsonlSink(str(tmp_path))
    feed_result(sink, res, meta)
    feed_result(sink, res, meta)
    sink.flush()

    batches = []
    out = {}

    def go():
        out["report"] = Replayer().watch(
            str(tmp_path), poll_s=0.05, max_runs=4, idle_timeout_s=60,
            progress=lambda rep, n: batches.append((rep.replayed, n)))

    t = threading.Thread(target=go, daemon=True)
    t.start()
    deadline = time.monotonic() + 30
    while not batches and time.monotonic() < deadline:
        time.sleep(0.01)                 # initial 2 runs observed first...
    assert batches, "watch never saw the initial runs"
    feed_result(sink, res, meta)         # ...then the live append
    feed_result(sink, res, meta)
    sink.flush()
    t.join(60)
    assert not t.is_alive()
    sink.close()

    report = out["report"]
    assert report.replayed == 4
    assert all(r.discrepancy == 0.0 for r in report.rows)
    assert [r.index for r in report.rows] == [0, 1, 2, 3]
    assert len(batches) >= 2             # incremental, not one batch
    assert batches[0][0] == 2 and batches[-1][0] == 4


def test_serve_replay_watch_wiring(tmp_path, capsys):
    """serve --mode replay --watch drains an existing archive and exits at
    --limit (the appended-while-running path is covered above)."""
    import argparse

    from repro.launch.serve import _replay_main

    _write_archive(tmp_path, ["hanoi"])
    args = argparse.Namespace(
        archive_dir=str(tmp_path), archive_prefix="traces",
        replay_mechanism="", limit=len(BENCH_NAMES), watch=True,
        watch_poll_ms=50.0, watch_idle_s=30.0)
    _replay_main(args)
    out = capsys.readouterr().out
    assert f"{len(BENCH_NAMES)} replayed; rolling" in out
    assert "[replay] overall:" in out


def test_index_scan_matches_reader_on_degraded_archives(tmp_path):
    """scan_archive and ArchiveReader must share ONE definition of an
    intact run — drift regression for: decodable-but-invalid issue/end
    fields (reader voids the run, scanner must too) and a non-last file
    whose final line lacks a trailing newline but parses (reader yields
    the run, scanner must too)."""
    from repro.archive.index import scan_archive

    sink = _write_archive(tmp_path, ["hanoi", "simt_stack"])
    assert len(sink.paths) >= 3

    # a decodable issue line with missing fields, mid-run in file 0
    first = sink.paths[0]
    lines = open(first, encoding="utf-8").read().splitlines(keepends=True)
    lines[1] = '{"event":"issue"}\n'                 # no pc/mask
    open(first, "w", encoding="utf-8").writelines(lines)
    # a NON-last file whose final (valid) line lacks its newline
    mid = sink.paths[1]
    raw = open(mid, encoding="utf-8").read()
    assert raw.endswith("\n")
    open(mid, "w", encoding="utf-8").write(raw[:-1])
    # and a truncated LAST file (partial final line)
    last = sink.paths[-1]
    raw = open(last, encoding="utf-8").read()
    open(last, "w", encoding="utf-8").write(raw[:-20])

    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    _, entries = scan_archive(str(tmp_path))
    assert len(entries) == len(runs)                 # same runs, same order
    for entry, run in zip(entries, runs):
        got = reader.get(entry.run_id)
        assert dict(got.meta) == dict(run.meta)
        assert got.trace == run.trace
        assert entry.program == run.program
    # the voided-run cases really happened (the fixtures did their job)
    assert reader.report.corrupt_lines >= 1
    assert reader.report.truncated_runs >= 1


# ---------------------------------------------------------------------------
# incremental tailing (ISSUE 6 satellite): watch must not re-read the archive
# ---------------------------------------------------------------------------

def test_tailer_unchanged_archive_does_no_rereads(tmp_path):
    """THE no-re-read regression: polling an unchanged archive opens zero
    files and reads zero bytes — watch cost is O(appended), not O(archive)."""
    from repro.archive import ArchiveTailer

    sink = _write_archive(tmp_path, ["hanoi"])
    assert len(sink.paths) >= 2
    tailer = ArchiveTailer(str(tmp_path))
    runs = tailer.poll()
    assert len(runs) == len(BENCH_NAMES)
    opened, read = tailer.stats.files_opened, tailer.stats.bytes_read
    assert opened >= len(sink.paths) and read > 0
    for _ in range(5):
        assert tailer.poll() == []
    assert tailer.stats.files_opened == opened       # never even open()ed
    assert tailer.stats.bytes_read == read           # zero bytes re-read
    assert tailer.stats.full_rescans == 0
    assert tailer.stats.polls == 6
    assert tailer.report.complete


def test_tailer_incremental_append_and_rotation(tmp_path):
    """Appends — including ones that rotate to a new file — are picked up
    from per-file offsets without a full rescan, and the tailed runs are
    bit-equal to a fresh whole-archive read."""
    from repro.archive import ArchiveTailer

    res = SIM.run(_bench("DIAMOND"), CFG)
    meta = run_meta("hanoi", as_request(_bench("DIAMOND"), CFG))
    sink = RotatingJsonlSink(str(tmp_path), max_bytes=4096)
    feed_result(sink, res, meta)
    sink.flush()

    tailer = ArchiveTailer(str(tmp_path))
    assert len(tailer.poll()) == 1
    for _ in range(6):
        feed_result(sink, res, meta)
    sink.flush()
    new = tailer.poll()
    assert len(new) == 6
    assert len(sink.paths) > 1                       # rotation happened...
    assert tailer.stats.full_rescans == 0            # ...with no rescan
    assert tailer.poll() == []
    sink.close()

    fresh = ArchiveReader(str(tmp_path)).runs()
    assert len(fresh) == 7
    assert [r.trace for r in new] == [r.trace for r in fresh[1:]]
    assert tailer.report.complete


def test_tailer_buffers_partial_tail_line_until_complete(tmp_path):
    """An unterminated tail line of the newest file is not consumed (and
    not damage): the offset stays before it until the writer finishes."""
    from repro.archive import ArchiveTailer

    sink = _write_archive(tmp_path, ["hanoi"], max_bytes=1 << 20)
    tailer = ArchiveTailer(str(tmp_path))
    n = len(tailer.poll())
    last = sink.paths[-1]

    # hand-append half an event line (a writer mid-flush)
    whole = '{"event":"begin","mechanism":"hanoi"}\n'
    with open(last, "a", encoding="utf-8") as fh:
        fh.write(whole[:14])
    assert tailer.poll() == []
    assert not tailer.report.complete                # pending partial line
    read_before = tailer.stats.bytes_read
    with open(last, "a", encoding="utf-8") as fh:
        fh.write(whole[14:])
    assert tailer.poll() == []                       # begin alone: no run yet
    # only the delta was read, and the partial prefix only re-read once
    assert tailer.stats.bytes_read - read_before == len(whole)
    assert tailer.stats.runs == n


def test_tailer_rescans_on_compaction_without_duplicates(tmp_path):
    """Compaction rewrites files under the tailer: it must detect the
    invalidated offsets, rescan, and not re-emit already-seen runs."""
    from repro.archive import ArchiveTailer

    sink = _write_archive(tmp_path, ["hanoi"])
    # corrupt one mid-run line so compaction has debris to drop (a clean
    # archive compacts byte-identically -- offsets stay valid, no rescan)
    first_file = sink.paths[0]
    lines = open(first_file, encoding="utf-8").read().splitlines(
        keepends=True)
    lines[1] = "{not json}\n"
    open(first_file, "w", encoding="utf-8").writelines(lines)

    tailer = ArchiveTailer(str(tmp_path))
    first = tailer.poll()
    assert len(first) == len(BENCH_NAMES) - 1        # one run voided
    compact(str(tmp_path))                           # drops the debris
    again = tailer.poll()
    assert again == []                               # no re-emission
    assert tailer.stats.full_rescans == 1
    assert tailer.report.complete


def test_watch_uses_tailer_not_full_rewalks(tmp_path):
    """Replayer.watch is wired through ArchiveTailer: after the initial
    drain, an idle-timeout watch does zero additional archive reads."""
    from repro.archive import ArchiveTailer
    import repro.archive.replay as replay_mod

    _write_archive(tmp_path, ["hanoi"])
    seen = {}
    orig_poll = ArchiveTailer.poll

    def counting_poll(self):
        out = orig_poll(self)
        seen.setdefault("tailer", self)
        return out

    ArchiveTailer.poll = counting_poll
    try:
        report = Replayer().watch(str(tmp_path), poll_s=0.01,
                                  idle_timeout_s=0.2)
    finally:
        ArchiveTailer.poll = orig_poll
    assert report.replayed == len(BENCH_NAMES)
    tailer = seen["tailer"]
    assert tailer.stats.polls >= 2                   # it did keep polling...
    assert tailer.stats.bytes_read > 0
    first_read = tailer.stats.bytes_read
    # ...but every post-drain poll read zero bytes (cheap stat-only ticks)
    assert tailer.stats.files_opened <= len(tailer.report.files) + 1
    assert first_read == sum(os.path.getsize(p) for p in tailer.report.files)


# ---------------------------------------------------------------------------
# offline IPC re-derivation (ISSUE 6): archived cells re-price offline
# ---------------------------------------------------------------------------

def test_sm_archive_carries_timing_stamp_and_rederives(tmp_path):
    from repro.archive import TimingRederivation

    sink = RotatingJsonlSink(str(tmp_path))
    with SimulationService(default_mechanism="hanoi", workers=1,
                           archive=sink) as svc:
        sm = svc.submit_sm(_bench("DIAMOND"), CFG, n_warps=3,
                           inner="hanoi").result()
    sink.flush()
    sink.close()
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    # every warp's begin meta carries the cell's sm_timing stamp
    for r in runs:
        stamp = r.meta["sm_timing"]
        assert stamp["cycles"] == sm.cycles
        assert stamp["thread_instructions"] == sm.thread_instructions
        assert stamp["busy_cycles"] == sm.busy_cycles
        assert (stamp["busy_cycles"] + stamp["scoreboard_stall_cycles"]
                + stamp["memory_stall_cycles"]) == stamp["cycles"]

    cells = Replayer().rederive_timing(reader)
    assert len(cells) == 1
    td = cells[0]
    assert isinstance(td, TimingRederivation)
    assert td.n_warps == 3 and td.policy == "round_robin"
    # default config == live config: the re-derivation matches the stamp
    assert td.matches_archive
    assert td.result.cycles == sm.cycles
    assert td.result.thread_instructions == sm.thread_instructions
    assert td.ipc == pytest.approx(sm.ipc)

    # offline what-if: re-price under different latencies -> same work,
    # different cycles, stamp no longer matches
    from repro.core.timing import TimingConfig
    slow = Replayer().rederive_timing(
        reader, timing_cfg=TimingConfig(alu_latency=50, control_latency=50,
                                        memory_latency=300,
                                        atomic_latency=300))[0]
    assert slow.result.thread_instructions == sm.thread_instructions
    assert slow.result.cycles > sm.cycles
    assert not slow.matches_archive


def test_cli_rederive_timing(tmp_path, capsys):
    from repro.archive.__main__ import main

    sink = RotatingJsonlSink(str(tmp_path))
    with SimulationService(default_mechanism="hanoi", workers=1,
                           archive=sink) as svc:
        svc.submit_sm(_bench("DIAMOND"), CFG, n_warps=2,
                      inner="hanoi").result()
    sink.flush()
    sink.close()
    assert main([str(tmp_path), "--rederive-timing"]) == 0
    out = capsys.readouterr().out
    assert "[timing] cell" in out and "stamp=match" in out

    empty = tmp_path / "empty"
    empty.mkdir()
    assert main([str(empty), "--rederive-timing"]) == 0
    assert "no SM cells" in capsys.readouterr().out
