"""Annotation synthesis (repro.analysis.transform) + static cost model.

The headline contract: ``strip_annotations`` → ``synthesize_annotations``
round-trips the benchmark suite and the progen corpus — for every program
except FIG5 *bit-for-bit* (which makes trace equivalence under every
mechanism trivial), and for FIG5 (whose hand-forced B0 reuse + R0 spill
the allocator legitimately improves away) equivalent modulo scratch spill
registers and scheduler interleaving.  Everything the synthesizer emits
must pass ``verify_program(strict=True)`` with zero errors.
"""
import numpy as np
import pytest

from repro.analysis import (StaticAnalysisError, TransformError,
                            analyze_program, estimate, rank_correlation,
                            strip_annotations, synthesize_annotations,
                            verify_program)
from repro.analysis.transform import ANNOTATION_OPS
from repro.core import compile_structured
from repro.core import programs as P
from repro.core.asm import assemble
from repro.core.isa import F_DST, F_OP, MachineConfig, Op
from repro.core.programs import make_suite
from repro.core.structured import If, Raw, Seq
from repro.engine import Simulator, iter_mechanisms
from tests.progen import corpus, make_program

W8 = MachineConfig(n_threads=8)
W4 = MachineConfig(n_threads=4)
SUITE = make_suite(W8, datasets=1)
SIM = Simulator("hanoi")

# the one suite program whose round-trip is equivalent-but-not-bit-equal:
# FIG5 hand-forces B0 reuse with an R0 spill where the allocator simply
# uses two of the eight Bx registers
KNOWN_DEVIATIONS = {"FIG5"}

SINGLE_WARP = [m.name for m in iter_mechanisms() if "composite" not in m.tags]


def _roundtrip(program, cfg):
    s = strip_annotations(program, cfg)
    return s, synthesize_annotations(s.program, cfg)


# ---------------------------------------------------------------------------
# round-trip: suite + progen corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bench", SUITE, ids=[b.name for b in SUITE])
def test_roundtrip_suite_bit_equal(bench):
    s, r = _roundtrip(bench.program, W8)
    verify_program(r.program, W8, strict=True)
    if bench.name in KNOWN_DEVIATIONS:
        assert not np.array_equal(r.program, np.asarray(bench.program))
    else:
        np.testing.assert_array_equal(
            r.program, np.asarray(bench.program),
            err_msg=f"{bench.name}: strip→synthesize is not bit-equal")


def test_roundtrip_corpus_bit_equal():
    deviations = []
    for label, prog, cfg in corpus(20):
        s, r = _roundtrip(prog, cfg)
        verify_program(r.program, cfg, strict=True)
        if not np.array_equal(r.program, np.asarray(prog)):
            deviations.append(label)
    assert not deviations, f"non-bit-equal round-trips: {deviations}"


def _spill_regs(*programs) -> list[int]:
    regs = set()
    for prog in programs:
        for row in np.asarray(prog):
            if row[F_OP] == int(Op.BMOV_B2R):
                regs.add(int(row[F_DST]))
    return sorted(regs)


@pytest.mark.parametrize("mech", SINGLE_WARP)
def test_fig5_roundtrip_equivalent_under_every_mechanism(mech):
    """The one deviating program: trace-equivalent modulo scratch state.

    Projection drops the annotation pcs absent from the composed pc maps;
    the surviving (pc, mask) events must agree as a multiset everywhere
    (scheduling-sensitive mechanisms may interleave the split paths
    differently around the changed instruction count) and in exact order
    under the deterministic stack baseline.  Architectural state must
    agree except the BMOV spill registers, which are mechanism scratch.
    """
    bench = next(b for b in SUITE if b.name == "FIG5")
    s, r = _roundtrip(bench.program, W8)
    back = dict(r.pc_map)
    comp = {o: back[m] for o, m in dict(s.pc_map).items() if m in back}
    vals = set(comp.values())

    ra = SIM.run(bench.program, W8, mechanism=mech)
    rb = SIM.run(r.program, W8, mechanism=mech)
    ta = [(comp[pc], int(m)) for pc, m in ra.trace if pc in comp]
    tb = [(pc, int(m)) for pc, m in rb.trace if pc in vals]
    assert sorted(ta) == sorted(tb), f"{mech}: projected traces differ"
    if mech == "simt_stack":
        assert ta == tb, "stack baseline must match in exact order"
    assert ra.status == rb.status
    np.testing.assert_array_equal(ra.mem, rb.mem)
    keep = [c for c in range(ra.regs.shape[1])
            if c not in _spill_regs(bench.program, r.program)]
    np.testing.assert_array_equal(ra.regs[:, keep], rb.regs[:, keep])


def test_progen_unannotated_variant_preserves_streams():
    (pa, ma), cfg = make_program(3, 8, sync_features=True)
    (pu, mu), cfg_u = make_program(3, 8, sync_features=True,
                                   unannotated=True)
    np.testing.assert_array_equal(ma, mu)       # same rng draws
    assert cfg == cfg_u
    assert len(pu) < len(pa)                    # something was stripped
    # the stripped variant resynthesizes back to the annotated original
    r = synthesize_annotations(pu, cfg)
    np.testing.assert_array_equal(r.program, np.asarray(pa))


def test_unannotated_corpus_synthesizes_strict_clean():
    for label, prog, cfg in corpus(10, unannotated=True):
        r = synthesize_annotations(prog, cfg)
        report = verify_program(r.program, cfg, strict=True)
        assert report.ok, label


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_ipdom_at_virtual_sink_is_skipped():
    prog = assemble("""
        ISETP.LT P0, R0, 4
    @P0 BRA away
        EXIT
    away:
        EXIT
    """)
    r = synthesize_annotations(prog, W8)
    assert not r.changed
    assert [x.code for x in r.skipped] == ["ipdom-sink"]
    np.testing.assert_array_equal(r.program, prog)


def test_spill_chain_matches_structured_compiler():
    """Nesting deeper than the Bx file: the allocator must reproduce the
    structured compiler's BMOV spill chain bit-for-bit."""
    tiny = MachineConfig(n_threads=8, n_bx=2)
    cond = ["ISETP.LT P0, R1, 6"]
    body = Raw(["IADDI R5, R5, 1"])
    nest = Seq([Raw(["LANEID R1", "MOVR R5, R1"]),
                If(cond, 0,
                   If(cond, 0,
                      If(cond, 0, body, body),
                      body),
                   body),
                Raw(["IADDI R5, R5, 7"])])
    prog = compile_structured(nest, tiny)
    assert any(int(r[F_OP]) == int(Op.BMOV_B2R) for r in np.asarray(prog))
    s, r = _roundtrip(prog, tiny)
    assert r.spills > 0
    np.testing.assert_array_equal(r.program, np.asarray(prog))
    # strict would trip on the stack-depth warn — which is exactly the
    # condition that forced the spill chain; errors must still be zero
    report = verify_program(r.program, tiny)
    assert "stack-depth" in report.codes()


def test_yield_insertion_is_idempotent():
    spin = assemble(P.SPINLOCK_NO_YIELD_ASM)
    once = synthesize_annotations(spin, W4)
    assert once.yields == 1
    twice = synthesize_annotations(once.program, W4)
    assert not twice.changed
    np.testing.assert_array_equal(twice.program, once.program)
    # an already-YIELDed spinlock is untouched from the start
    slock = next(b for b in SUITE if b.name == "SLOCK")
    r = synthesize_annotations(slock.program, W8)
    assert not r.changed


def test_call_ret_crossing_regions_are_refused():
    calls = next(b for b in SUITE if b.name == "CALLS")
    stripped = strip_annotations(calls.program, W8)
    assert not stripped.changed                 # strip never touches them
    r = synthesize_annotations(calls.program, W8)
    assert not r.changed and not r.refused      # fully annotated already
    # an *unannotated* divergent branch in a CALL/RET program: the region
    # would shift the MOV-staged return address — must refuse, not edit
    unannotated = assemble("""
        LANEID R1
        MOV R9, ret1
        ISETP.GE P0, R1, 4
    @P0 BRA docall
        MOV R2, 5
        BRA join
    docall:
        CALL square
    ret1:
    join:
        IADDI R4, R2, 8
        EXIT
    square:
        MOVR R2, R1
        IMUL R2, R2, R2
        RET R9
    """)
    r = synthesize_annotations(unannotated, W8)
    assert not r.changed
    assert r.refused and all(x.code == "call-ret" for x in r.refused)
    assert "CALL" in r.refused[0].message
    np.testing.assert_array_equal(r.program, unannotated)
    with pytest.raises(TransformError, match="refused"):
        synthesize_annotations(unannotated, W8, strict=True)


def test_spinlock_no_yield_repair_terminates_and_clears_warning():
    spin = assemble(P.SPINLOCK_NO_YIELD_ASM)
    assert "spin-loop" in analyze_program(spin, W4).codes()
    r = synthesize_annotations(spin, W4)
    assert "spin-loop" not in analyze_program(r.program, W4).codes()
    res = SIM.run(r.program, W4, mechanism="hanoi")
    assert res.ok
    assert int(res.mem[1]) == 4                 # every lane took the lock


# ---------------------------------------------------------------------------
# static cost model
# ---------------------------------------------------------------------------

def test_cost_estimate_rank_correlates_with_cycle_engine():
    from repro.timing import CycleConfig, simulate_cycle
    est, cyc = [], []
    for bench in SUITE:
        res = SIM.run(bench.program, W8, mechanism="hanoi")
        tr = simulate_cycle([res.trace], bench.program, 8, CycleConfig())
        est.append(estimate(bench.program, W8).issue_cycles)
        cyc.append(tr.cycles)
    rho = rank_correlation(est, cyc)
    assert rho >= 0.70, f"Spearman rho {rho:.3f} below the 0.70 gate"


def test_cost_estimate_structure_fields():
    gaus = next(b for b in SUITE if b.name == "GAUS0")
    e = estimate(gaus.program, W8)
    assert e.issue_cycles > 0 and e.weighted_instructions > 0
    assert e.stack_depth >= 1 and e.region_sizes
    assert 0.0 < e.divergent_fraction < 1.0
    assert 0.0 <= e.stall_fraction <= 1.0
    slock = next(b for b in SUITE if b.name == "SLOCK")
    assert estimate(slock.program, W8).spin_loops == 1
    # memory latency moves the estimate in the right direction
    from repro.timing import CycleConfig
    slow = estimate(gaus.program, W8,
                    cycle_cfg=CycleConfig(memory_latency=300))
    assert slow.issue_cycles > e.issue_cycles


def test_rank_correlation_basics():
    assert rank_correlation([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert rank_correlation([1, 2, 3], [3, 2, 1]) == pytest.approx(-1.0)
    assert rank_correlation([1, 1, 1], [1, 2, 3]) == 0.0
    assert rank_correlation([], []) == 0.0
    with pytest.raises(ValueError):
        rank_correlation([1], [1, 2])


# ---------------------------------------------------------------------------
# platform wiring: cache key, CLI, Simulator, service
# ---------------------------------------------------------------------------

def test_analyze_cache_key_includes_machine_knobs():
    """Same bytes under different configs must not share a report."""
    cond = ["ISETP.LT P0, R1, 6"]
    body = Raw(["IADDI R5, R5, 1"])
    nest = Seq([Raw(["LANEID R1", "MOVR R5, R1"]),
                If(cond, 0,
                   If(cond, 0, If(cond, 0, body, body), body),
                   body)])
    prog = compile_structured(nest, MachineConfig(n_threads=8))
    deep = analyze_program(prog, MachineConfig(n_threads=8, n_bx=2))
    assert "stack-depth" in deep.codes()
    assert "stack-depth" not in analyze_program(prog, W8).codes()
    # n_regs shows up in the spill-capacity hint — distinct cache entries
    msg16 = next(d for d in analyze_program(
        prog, MachineConfig(n_threads=8, n_bx=2, n_regs=16)).warnings
        if d.code == "stack-depth").message
    msg8 = next(d for d in analyze_program(
        prog, MachineConfig(n_threads=8, n_bx=2, n_regs=8)).warnings
        if d.code == "stack-depth").message
    assert msg16 != msg8 and "16" in msg16 and "8" in msg8


def test_lint_cli_fix_select_ignore_github(tmp_path, capsys):
    from repro.analysis.__main__ import main
    spin = tmp_path / "spin.asm"
    spin.write_text(P.SPINLOCK_NO_YIELD_ASM)
    assert main([str(spin), "--strict"]) == 1            # warn fails
    capsys.readouterr()
    assert main([str(spin), "--strict", "--fix"]) == 0   # repaired
    out = capsys.readouterr().out
    assert "yield(s)" in out
    assert main([str(spin), "--strict", "--ignore", "spin-loop"]) == 0
    assert main([str(spin), "--strict", "--select", "bad-target"]) == 0
    assert main([str(spin), "--strict", "--select", "spin-loop"]) == 1
    capsys.readouterr()
    assert main([str(spin), "--format=github"]) == 0
    out = capsys.readouterr().out
    assert "::warning " in out and "title=spin-loop" in out
    assert f"file={spin}" in out


def test_simulator_synthesize_kwarg():
    spin = assemble(P.SPINLOCK_NO_YIELD_ASM)
    with pytest.raises(StaticAnalysisError):
        SIM.run(spin, W4, mechanism="hanoi", verify="strict")
    res = SIM.run(spin, W4, mechanism="hanoi", verify="strict",
                  synthesize=True)
    assert res.ok and int(res.mem[1]) == 4
    outs = SIM.run_batch([spin, spin], W4, mechanism="hanoi",
                         verify="strict", synthesize=True)
    assert all(r.ok for r in outs)


def test_service_auto_annotate_repairs_and_counts():
    from repro.service import SimulationService
    spin = assemble(P.SPINLOCK_NO_YIELD_ASM)
    with SimulationService(default_mechanism="hanoi", verify="strict",
                           auto_annotate=True, workers=1) as svc:
        t = svc.submit(spin, W4)
        svc.flush()
        res = t.result(timeout=30)
        assert res.ok and int(res.mem[1]) == 4
        stats = svc.stats()
        assert stats.repaired == 1 and stats.rejected == 0
        # irreparable programs still reject: reconvergence is an error
        # the synthesizer cannot undo
        bad = svc.submit(P.fig6_no_break_program(), W8)
        svc.flush()
        with pytest.raises(StaticAnalysisError):
            bad.result(timeout=30)
        assert svc.stats().rejected == 1
