"""SS III / SS VI-C: SIMT-induced deadlock on pre-Volta, fixed by YIELD +
late BSYNC on Hanoi.  Mutual exclusion is checked observably: the critical
section does a non-atomic read-modify-write on a shared counter.

Runs through the canonical ``repro.engine`` API (the ``interp.run_*``
entry points are deprecated shims)."""
import pytest

from repro.core import MachineConfig
from repro.core.programs import spinlock_no_yield_program, spinlock_program
from repro.engine import Simulator

SIM = Simulator("hanoi")


@pytest.mark.parametrize("w", [2, 4, 8, 16, 32])
def test_hanoi_spinlock_completes_and_excludes(w):
    cfg = MachineConfig(n_threads=w, max_steps=40_000)
    r = SIM.run(spinlock_program(), cfg)
    assert not r.deadlocked, "Hanoi must complete the spinlock (SS VI-C)"
    assert r.finished == cfg.full_mask
    assert r.mem[0] == 0, "lock released at the end"
    assert r.mem[1] == w, "non-atomic counter == W proves mutual exclusion"


def test_yield_removed_deadlocks_on_hanoi():
    """The paper's SS V-G ablation: removing YIELD from the binary makes the
    program hang on real Turing hardware — and on Hanoi."""
    cfg = MachineConfig(n_threads=4, max_steps=20_000)
    r = SIM.run(spinlock_no_yield_program(), cfg)
    assert r.deadlocked
    assert r.mem[1] < 4     # not every thread made it through the CS


def test_simt_stack_spinlock_deadlocks():
    """SS III: the pre-Volta mechanism deadlocks on the Fig 3 spinlock no
    matter the path priority."""
    cfg = MachineConfig(n_threads=4, max_steps=20_000)
    r = SIM.run(spinlock_program(), cfg, mechanism="simt_stack")
    assert r.deadlocked


def test_spinlock_trace_interleaves_paths():
    """Post-Volta behavior (Fig 4): the trace must interleave the loop path
    and the critical-section path — impossible pre-Volta (constraint 1)."""
    cfg = MachineConfig(n_threads=4, max_steps=40_000)
    r = SIM.run(spinlock_program(), cfg)
    # find a loop pc and a critical-section pc and check the trace switches
    # from loop -> CS -> loop at least once
    prog = spinlock_program()
    from repro.core import Op
    cas_pc = next(pc for pc in range(prog.shape[0])
                  if prog[pc, 0] == Op.ATOMCAS)
    stg_pc = next(pc for pc in range(prog.shape[0]) if prog[pc, 0] == Op.STG)
    seq = [pc for pc, _ in r.trace if pc in (cas_pc, stg_pc)]
    # CAS ... STG ... CAS again proves interleaved execution of both paths
    first_stg = seq.index(stg_pc)
    assert cas_pc in seq[first_stg + 1:]
