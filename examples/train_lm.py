"""End-to-end training driver (brief deliverable b): train a ~100M-param
llama-style model with the full production runtime — sharded params, the
deterministic pipeline, async checkpoints, restart safety, straggler monitor.

On this CPU container the default is a ~25M model for wall-clock sanity
(--big selects the ~110M config; on TPU the same driver takes the full
configs through launch/train.py).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 150] [--big]
"""
import argparse

from repro.configs import get_config
from repro.launch import train as T
from repro.models import model_struct, param_count
from repro.models.base import uniform_plan


def lm_config(big: bool):
    base = get_config("llama3.2-1b")
    if big:     # ~110M params
        return base.replace(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
            vocab_size=32000, layer_plan=uniform_plan("global", 12),
        ).validate()
    return base.replace(  # ~25M params
        n_layers=6, d_model=384, n_heads=6, n_kv_heads=2, d_ff=1536,
        vocab_size=8192, layer_plan=uniform_plan("global", 6),
    ).validate()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = lm_config(args.big)
    n = param_count(model_struct(cfg))
    print(f"[example] model: {n/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    # monkeypatch the registry so the generic driver picks up our config
    import repro.launch.train as TR
    TR.get_config = lambda name, smoke=True: cfg
    res = TR.train("custom-lm", smoke=True, steps=args.steps,
                   batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                   ckpt_every=50, lr=3e-3, log_every=10)
    first, last = res["losses"][0], res["losses"][-1]
    print(f"[example] loss {first:.3f} -> {last:.3f} over {args.steps} steps")
    assert last < first, "training must make progress"


if __name__ == "__main__":
    main()
