"""Dry-run a single (arch x shape x mesh) cell and print its roofline terms.

This is the public API the EXPERIMENTS.md tables are built from.  Must be a
fresh process (the 512-device flag is set before jax import).

Run:  PYTHONPATH=src python examples/dryrun_cell.py --arch gemma3-4b \\
          --shape decode_32k [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    rec = run_cell(args.arch, args.shape, args.multi_pod)
    if rec["status"] != "ok":
        print(rec)
        return
    ro = rec["roofline"]
    print(f"\n[example] {args.arch} x {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'} mesh)")
    print(f"  compute    {ro['compute_s']*1e3:9.2f} ms")
    print(f"  memory     {ro['memory_s']*1e3:9.2f} ms")
    print(f"  collective {ro['collective_s']*1e3:9.2f} ms")
    print(f"  dominant:  {ro['dominant']}")
    print(f"  collectives by kind: {ro['coll_by_kind']}")
    print(f"  useful-FLOP fraction: {rec['useful_flop_frac']:.2f}")


if __name__ == "__main__":
    main()
