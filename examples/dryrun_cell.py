"""Dry-run a single cell and print its headline terms.

Two cell families share this entry point:

* roofline cells — one (arch x shape x mesh) combination through the HLO
  dry-run path (the EXPERIMENTS.md tables).  Must be a fresh process (the
  512-device flag is set before jax import).
* control-flow cells (``--cf-bench NAME``) — one (benchmark x mechanism
  pair) through the unified ``repro.engine`` API: trace discrepancy, IPC
  delta and SIMD utilization for that single cell.

Run:  PYTHONPATH=src python examples/dryrun_cell.py --arch gemma3-4b \\
          --shape decode_32k [--multi-pod]
      PYTHONPATH=src python examples/dryrun_cell.py --cf-bench BFSD \\
          [--cf-mechanisms hanoi,turing_oracle]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse


def run_cf_cell(bench_name: str, mechanisms: list[str]) -> None:
    from repro.core import MachineConfig
    from repro.core.programs import make_suite
    from repro.engine import Simulator

    cfg = MachineConfig(n_threads=32, mem_size=256, max_steps=60_000)
    suite = make_suite(cfg)
    bench = next((b for b in suite if b.name == bench_name), None)
    if bench is None:
        raise SystemExit(f"unknown benchmark {bench_name!r}; available: "
                         + ", ".join(b.name for b in suite))
    a, b = mechanisms
    report = Simulator().compare(mechanisms, [bench], cfg, pairs=[(a, b)])
    row = report.pair(a, b)[0]
    print(f"\n[example] control-flow cell {bench_name} x ({a} vs {b})")
    print(f"  status         {row.status_a} / {row.status_b}")
    print(f"  discrepancy    {row.discrepancy_pct:8.2f} %")
    print(f"  ipc            {row.ipc_a:8.3f} vs {row.ipc_b:8.3f} "
          f"({row.ipc_delta_pct:+.1f}%)")
    print(f"  simd util      {row.util_a:8.3f} vs {row.util_b:8.3f}")
    print(f"  trace lengths  {row.trace_len_a} vs {row.trace_len_b}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--cf-bench", default=None,
                    help="run a control-flow cell for this benchmark name "
                         "(e.g. BFSD) instead of a roofline cell")
    ap.add_argument("--cf-mechanisms", default="hanoi,turing_oracle",
                    help="comma-separated mechanism pair for --cf-bench")
    args = ap.parse_args()

    if args.cf_bench:
        mechs = [m.strip() for m in args.cf_mechanisms.split(",")]
        if len(mechs) != 2:
            raise SystemExit("--cf-mechanisms needs exactly two names")
        run_cf_cell(args.cf_bench, mechs)
        return

    from repro.launch.dryrun import run_cell
    rec = run_cell(args.arch, args.shape, args.multi_pod)
    if rec["status"] != "ok":
        print(rec)
        return
    ro = rec["roofline"]
    print(f"\n[example] {args.arch} x {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'} mesh)")
    print(f"  compute    {ro['compute_s']*1e3:9.2f} ms")
    print(f"  memory     {ro['memory_s']*1e3:9.2f} ms")
    print(f"  collective {ro['collective_s']*1e3:9.2f} ms")
    print(f"  dominant:  {ro['dominant']}")
    print(f"  collectives by kind: {ro['coll_by_kind']}")
    print(f"  useful-FLOP fraction: {rec['useful_flop_frac']:.2f}")


if __name__ == "__main__":
    main()
