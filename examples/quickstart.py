"""Quickstart: the paper's core artifacts in 60 seconds.

Everything runs through the unified ``repro.engine`` API — one Simulator,
any mechanism by name:

1. assemble the Fig 3/7 spinlock and watch pre-Volta (SIMT-Stack) deadlock
   while Hanoi completes it via YIELD + late BSYNC;
2. reproduce the Fig 6 early-reconvergence-with-BREAK walkthrough;
3. compare Hanoi's control-flow trace against the Turing-oracle heuristic
   (the paper's Fig 9 discrepancy metric) on a BFS-like benchmark;
4. show the Volta-style per-thread-PC scheduler's forward-progress
   guarantee (the YIELD-less spinlock terminates where Hanoi hangs) and a
   per-SM multi-warp interleaving run.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import MachineConfig, disassemble
from repro.core.programs import (fig6_program, make_suite,
                                 spinlock_no_yield_program, spinlock_program)
from repro.engine import Simulator, SimStatus

W = 8
CFG = MachineConfig(n_threads=W, max_steps=40_000)
sim = Simulator("hanoi")

# --- 1. spinlock: pre-Volta deadlock vs Hanoi ------------------------------
prog = spinlock_program()
print("=== spinlock (Fig 3/7) ===")
print(disassemble(prog))
pre = sim.run(prog, CFG, mechanism="simt_stack")
post = sim.run(prog, CFG, mechanism="hanoi")
print(f"\npre-Volta SIMT-Stack: status={pre.status.value} "
      f"(critical sections completed: {int(pre.mem[1])}/{W})")
print(f"Hanoi:                status={post.status.value} "
      f"counter={int(post.mem[1])}/{W} (mutual exclusion held)")
assert pre.status is SimStatus.OUT_OF_FUEL and post.status is SimStatus.OK

# --- 2. early reconvergence with BREAK (Fig 6) ------------------------------
r = sim.run(fig6_program(), MachineConfig(n_threads=4, max_steps=512))
print("\n=== Fig 6: BREAK enables reconvergence BEFORE the IPDom ===")
print(f"completed: {r.ok}; "
      f"early-reconverged mask seen in trace: "
      f"{any(m == 0b1110 for _, m in r.trace)}")

# --- 3. trace discrepancy vs the hardware heuristic (Fig 9) -----------------
CFG32 = MachineConfig(n_threads=32, max_steps=60_000)
bench = next(b for b in make_suite(CFG32) if b.name == "BFSD")
report = sim.compare(["hanoi", "turing_oracle"], [bench], CFG32,
                     pairs=[("hanoi", "turing_oracle")], timing=False)
row = report.pair("hanoi", "turing_oracle")[0]
print("\n=== Fig 9/10: BFSD — Hanoi enforces reconvergence, hardware skips ===")
print(f"trace discrepancy: {row.discrepancy_pct:.1f}%")
print(f"SIMD utilization:  hanoi={row.util_a:.3f} hw={row.util_b:.3f}")

# --- 4. post-Volta per-thread PCs + per-SM multi-warp interleaving ----------
noyield = spinlock_no_yield_program()
hang = sim.run(noyield, CFG)                       # Hanoi: SS V-G ablation
its = sim.run(noyield, CFG, mechanism="volta_itps")
print("\n=== YIELD-less spinlock: stack mechanisms hang, per-thread PCs "
      "don't ===")
print(f"Hanoi:      status={hang.status.value} (needs YIELD to make "
      f"progress)")
print(f"volta_itps: status={its.status.value} counter={int(its.mem[1])}/{W} "
      f"(scheduler's forward-progress guarantee)")
assert not hang.ok and its.ok and int(its.mem[1]) == W

bench = next(b for b in make_suite(CFG) if b.name == "RBFS0")
sm = sim.run_sm(bench, CFG, n_warps=4, inner="hanoi",
                policy="greedy_then_oldest")
print(f"\n=== per-SM: 4 warps of RBFS0 under GTO ===")
print(f"status={sm.status.value} slots={sm.steps} cycles={sm.cycles} "
      f"thread-IPC={sm.ipc:.2f} util={sm.utilization:.3f}")
assert sm.ok
print("\nquickstart OK")
