"""Quickstart: the paper's core artifacts in 60 seconds.

1. assemble the Fig 3/7 spinlock and watch pre-Volta (SIMT-Stack) deadlock
   while Hanoi completes it via YIELD + late BSYNC;
2. reproduce the Fig 6 early-reconvergence-with-BREAK walkthrough;
3. compare Hanoi's control-flow trace against the Turing-oracle heuristic
   (the paper's Fig 9 discrepancy metric) on a BFS-like benchmark.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (MachineConfig, disassemble, run_hanoi,
                        run_simt_stack, simd_utilization)
from repro.core.programs import (fig6_program, make_suite, spinlock_program)
from repro.core.trace import discrepancy

W = 8
CFG = MachineConfig(n_threads=W, max_steps=40_000)

# --- 1. spinlock: pre-Volta deadlock vs Hanoi ------------------------------
prog = spinlock_program()
print("=== spinlock (Fig 3/7) ===")
print(disassemble(prog))
pre = run_simt_stack(prog, CFG)
post = run_hanoi(prog, CFG)
print(f"\npre-Volta SIMT-Stack: deadlocked={pre.deadlocked} "
      f"(critical sections completed: {int(pre.mem[1])}/{W})")
print(f"Hanoi:                deadlocked={post.deadlocked} "
      f"counter={int(post.mem[1])}/{W} (mutual exclusion held)")
assert pre.deadlocked and not post.deadlocked

# --- 2. early reconvergence with BREAK (Fig 6) ------------------------------
cfg4 = MachineConfig(n_threads=4, max_steps=512)
r = run_hanoi(fig6_program(), cfg4)
print("\n=== Fig 6: BREAK enables reconvergence BEFORE the IPDom ===")
print(f"completed: {not r.deadlocked}; "
      f"early-reconverged mask seen in trace: "
      f"{any(m == 0b1110 for _, m in r.trace)}")

# --- 3. trace discrepancy vs the hardware heuristic (Fig 9) -----------------
bench = next(b for b in make_suite(MachineConfig(n_threads=32,
                                                 max_steps=60_000))
             if b.name == "BFSD")
hanoi = run_hanoi(bench.program, MachineConfig(n_threads=32,
                                               max_steps=60_000),
                  init_mem=bench.init_mem)
hw = run_hanoi(bench.program, MachineConfig(n_threads=32, max_steps=60_000),
               init_mem=bench.init_mem,
               bsync_skip_pcs=bench.skip_bsync_pcs)
print("\n=== Fig 9/10: BFSD — Hanoi enforces reconvergence, hardware skips ===")
print(f"trace discrepancy: {100 * discrepancy(hanoi.trace, hw.trace):.1f}%")
print(f"SIMD utilization:  hanoi={simd_utilization(hanoi.trace, 32):.3f} "
      f"hw={simd_utilization(hw.trace, 32):.3f}")
print("\nquickstart OK")
