"""Quickstart: the paper's core artifacts in 60 seconds.

Everything runs through the unified ``repro.engine`` API — one Simulator,
any mechanism by name:

1. assemble the Fig 3/7 spinlock and watch pre-Volta (SIMT-Stack) deadlock
   while Hanoi completes it via YIELD + late BSYNC;
2. reproduce the Fig 6 early-reconvergence-with-BREAK walkthrough;
3. compare Hanoi's control-flow trace against the Turing-oracle heuristic
   (the paper's Fig 9 discrepancy metric) on a BFS-like benchmark;
4. show the Volta-style per-thread-PC scheduler's forward-progress
   guarantee (the YIELD-less spinlock terminates where Hanoi hangs) and a
   per-SM multi-warp interleaving run;
5. drive the queue-fed simulation service end to end: mixed-mechanism
   admission, signature coalescing onto the native vmap batch runner, a
   sharded (SM, policy) cell, rotating JSONL archival, and service stats;
6. read the durable archive back (``repro.archive``), replay every run
   offline — including the per-warp SM-cell runs, which archive with the
   full replay payload — and verify the replayed traces are bit-equal to
   what was served: the paper's Fig 9 discrepancy metric, from the archive;
7. index the archive (O(1) run lookup via the ``{prefix}.index.jsonl``
   sidecar), fetch one SM warp by id without scanning, and replay its
   whole cell;
8. price schedules on the event-driven cycle engine (``repro.timing``):
   the Fig 10 IPC delta with a per-cycle stall taxonomy via
   ``compare(timing="cycle")``, then re-derive an archived SM cell's IPC
   offline from its traces — bit-equal to the ``sm_timing`` stamp — and
   re-price it under different memory latencies without re-running
   anything;
9. run the same SM cell on ``sm_jax`` — the whole SM (lane execution +
   issue scheduling) as one ``jit(vmap)`` lane-parallel device program —
   and check it is bit-identical to the Python interleaver, with JIT
   compilation metered separately from execution wall time;
10. scale out: a 2-process service (``procs=2`` — signature-affine shard
    routing, numpy groups chunked across shards) warmed from a persistent
    compile cache (``warm_start=``), then restarted to prove the
    zero-re-trace contract from its own cache counters;
11. statically verify programs without running them (``repro.analysis``):
    lint the Fig 6 ablation (its missing BREAK is a ``reconvergence``
    error), watch the service reject it at admission with the full
    diagnostic report on the ticket, fix it, then rank archived runs by
    control-flow similarity from the sidecar index alone — the paper's
    pathologies, searchable without replaying a trace.

Run:  PYTHONPATH=src python examples/quickstart.py
(the ``main()`` guard is required: section 10 spawns worker processes and
the spawn start method re-imports this file in each child)
"""
import tempfile

from repro.core import MachineConfig, disassemble
from repro.core.programs import (fig6_program, make_suite,
                                 spinlock_no_yield_program, spinlock_program)
from repro.engine import RotatingJsonlSink, Simulator, SimStatus


def main():
    W = 8
    CFG = MachineConfig(n_threads=W, max_steps=40_000)
    sim = Simulator("hanoi")

    # --- 1. spinlock: pre-Volta deadlock vs Hanoi ------------------------------
    prog = spinlock_program()
    print("=== spinlock (Fig 3/7) ===")
    print(disassemble(prog))
    pre = sim.run(prog, CFG, mechanism="simt_stack")
    post = sim.run(prog, CFG, mechanism="hanoi")
    print(f"\npre-Volta SIMT-Stack: status={pre.status.value} "
          f"(critical sections completed: {int(pre.mem[1])}/{W})")
    print(f"Hanoi:                status={post.status.value} "
          f"counter={int(post.mem[1])}/{W} (mutual exclusion held)")
    assert pre.status is SimStatus.OUT_OF_FUEL and post.status is SimStatus.OK

    # --- 2. early reconvergence with BREAK (Fig 6) ------------------------------
    r = sim.run(fig6_program(), MachineConfig(n_threads=4, max_steps=512))
    print("\n=== Fig 6: BREAK enables reconvergence BEFORE the IPDom ===")
    print(f"completed: {r.ok}; "
          f"early-reconverged mask seen in trace: "
          f"{any(m == 0b1110 for _, m in r.trace)}")

    # --- 3. trace discrepancy vs the hardware heuristic (Fig 9) -----------------
    CFG32 = MachineConfig(n_threads=32, max_steps=60_000)
    bench = next(b for b in make_suite(CFG32) if b.name == "BFSD")
    report = sim.compare(["hanoi", "turing_oracle"], [bench], CFG32,
                         pairs=[("hanoi", "turing_oracle")], timing=False)
    row = report.pair("hanoi", "turing_oracle")[0]
    print("\n=== Fig 9/10: BFSD — Hanoi enforces reconvergence, hardware skips ===")
    print(f"trace discrepancy: {row.discrepancy_pct:.1f}%")
    print(f"SIMD utilization:  hanoi={row.util_a:.3f} hw={row.util_b:.3f}")

    # --- 4. post-Volta per-thread PCs + per-SM multi-warp interleaving ----------
    noyield = spinlock_no_yield_program()
    hang = sim.run(noyield, CFG)                       # Hanoi: SS V-G ablation
    its = sim.run(noyield, CFG, mechanism="volta_itps")
    print("\n=== YIELD-less spinlock: stack mechanisms hang, per-thread PCs "
          "don't ===")
    print(f"Hanoi:      status={hang.status.value} (needs YIELD to make "
          f"progress)")
    print(f"volta_itps: status={its.status.value} counter={int(its.mem[1])}/{W} "
          f"(scheduler's forward-progress guarantee)")
    assert not hang.ok and its.ok and int(its.mem[1]) == W

    bench = next(b for b in make_suite(CFG) if b.name == "RBFS0")
    sm = sim.run_sm(bench, CFG, n_warps=4, inner="hanoi",
                    policy="greedy_then_oldest")
    print(f"\n=== per-SM: 4 warps of RBFS0 under GTO ===")
    print(f"status={sm.status.value} slots={sm.steps} cycles={sm.cycles} "
          f"thread-IPC={sm.ipc:.2f} util={sm.utilization:.3f}")
    assert sm.ok

    # --- 5. the simulation service: coalesced, sharded, archived ----------------
    from repro.service import SimulationService

    suite8 = make_suite(CFG, datasets=1)
    benches = [b for b in suite8 if b.name in ("HOTS0", "GAUS0", "RBFS0",
                                               "DIAMOND")]
    with tempfile.TemporaryDirectory() as tmp:
        archive = RotatingJsonlSink(tmp, max_bytes=1 << 20)
        with SimulationService(default_mechanism="hanoi_jax", max_batch=8,
                               max_wait_s=0.01, workers=2,
                               archive=archive) as svc:
            # mixed admission: a homogeneous hanoi_jax group + numpy singles
            tickets = [svc.submit(b, CFG) for b in benches]            # jax
            tickets += [svc.submit(benches[0], CFG, mechanism=m)       # numpy
                        for m in ("hanoi", "simt_stack")]
            cell = svc.submit_sm(benches[2], CFG, n_warps=4, inner="hanoi",
                                 policy="greedy_then_oldest")          # SM shard
            svc.flush()
            results = [t.result() for t in tickets]
            sm_cell = cell.result()
            stats = svc.stats()
        archive.flush()
        archive.close()
        print("\n=== simulation service: one queue over every mechanism ===")
        print(f"completed={stats.completed} (sm_jobs={stats.sm_jobs}) "
              f"batches={stats.batches} native={stats.native_batches} "
              f"(x{stats.native_warps} warps) mean-fill={stats.mean_fill:.1f}")
        print(f"p50={stats.latency_p50_s * 1e3:.1f}ms "
              f"p99={stats.latency_p99_s * 1e3:.1f}ms "
              f"archived {archive.runs_written} runs -> "
              f"{len(archive.paths)} file(s)")
        # the homogeneous hanoi_jax group went through the native vmap runner
        assert all(r.meta["service"]["native"] for r in results[:4])
        assert all(r.ok for r in results) and sm_cell.ok
        # stats and archive both count warps: 6 single-warp + the 4 SM warps
        assert stats.completed == len(results) + sm_cell.n_warps
        assert archive.runs_written == stats.completed

        # --- 6. offline archive replay: Fig 9 from the durable archive ----------
        from repro.archive import ArchiveReader, Replayer

        reader = ArchiveReader(tmp)
        replay = Replayer().replay(reader)       # self-replay: integrity check
        print("\n=== archive replay: the served traces, re-run offline ===")
        print(f"read {reader.report.runs} archived runs "
              f"(clean={reader.report.clean}); replayed {replay.replayed} "
              f"incl. {len(replay.by_sm_cell())} SM cell(s)")
        print(f"self-replay discrepancy: "
              f"{replay.mean_discrepancy() * 100:.2f}% (bit-equal traces)")
        # deterministic mechanisms => replay reproduces the archive exactly
        assert replay.mean_discrepancy() == 0.0
        # the per-warp SM-cell archives now carry the full replay payload and
        # group back into their cell in the report
        assert replay.skipped_unreplayable == 0
        assert replay.replayed == archive.runs_written
        (cell_agg,) = replay.by_sm_cell().values()
        assert cell_agg.count == sm_cell.n_warps and cell_agg.max == 0.0

        # --- 7. archive index: O(1) lookup, then replay one cell by id ----------
        from repro.archive import ArchiveIndex

        idx = ArchiveIndex.build(tmp)            # sidecar {prefix}.index.jsonl
        # the replayed rows already know which runs were SM warps — fetch just
        # those by id (each get is one seek + read, no archive scan)
        sm_ids = [f"run-{row.index:06d}" for row in replay.rows
                  if row.sm_cell is not None]
        warp = reader.get(sm_ids[0])
        print("\n=== indexed lookup: one SM warp by run id ===")
        print(f"indexed {len(idx)} runs; {sm_ids[0]} -> warp "
              f"{warp.meta['sm_warp']}/{warp.meta['sm_warps']} of cell "
              f"{warp.sm_cell} ({warp.meta['sm_policy']}, {warp.program})")
        # replay exactly that cell: its warps, fetched by id
        cell_runs = [r for r in (reader.get(i) for i in sm_ids)
                     if r.sm_cell == warp.sm_cell]
        cell_replay = Replayer().replay(cell_runs)
        assert cell_replay.replayed == sm_cell.n_warps
        assert cell_replay.mean_discrepancy() == 0.0

        # --- 8. cycle-accurate timing: Fig 10 IPC delta + offline re-pricing ----
        from repro.core.timing import TimingConfig

        rep10 = sim.compare(["hanoi", "simt_stack"], [benches[0]], CFG,
                            timing="cycle")      # scoreboard cycle engine
        r10 = rep10.pair("hanoi", "simt_stack")[0]
        t_h = rep10.timing_results[(r10.program, "hanoi")]
        print("\n=== Fig 10 on the cycle engine: IPC delta + stall taxonomy ===")
        print(f"{r10.program}: ipc_delta={r10.ipc_delta_pct:+.2f}% "
              f"(hanoi ipc={t_h.ipc:.3f}; stalls {t_h.stall_breakdown})")
        assert t_h.cycles == (t_h.busy_cycles + t_h.scoreboard_stall_cycles
                              + t_h.memory_stall_cycles)
        # archived SM cells carry an sm_timing stamp: re-derive IPC offline
        # (bit-equal under the config it ran with), then re-price it under
        # slower memory without re-running any mechanism
        (td,) = Replayer().rederive_timing(reader)
        assert td.matches_archive and td.result.cycles == sm_cell.cycles
        (slow,) = Replayer().rederive_timing(
            reader, timing_cfg=TimingConfig(memory_latency=300))
        print(f"SM cell re-derived offline: ipc={td.ipc:.2f} "
              f"(stamp=match); at memory_latency=300: ipc={slow.ipc:.2f}")

    # --- 9. sm_jax: the whole SM as one jit(vmap) lane-parallel program ---------
    jax_cell = sim.run_sm(benches[2], CFG, n_warps=4, inner="hanoi_jax",
                          policy="greedy_then_oldest", sm_mechanism="sm_jax")
    py_cell = sim.run_sm(benches[2], CFG, n_warps=4, inner="hanoi",
                         policy="greedy_then_oldest")
    print("\n=== sm_jax: lane-parallel SM cell, bit-equal to the interleaver ===")
    print(f"{benches[2].name}: {jax_cell.n_warps} warps -> "
          f"slots={jax_cell.steps} cycles={jax_cell.cycles} "
          f"stalls={jax_cell.stall_breakdown}")
    print(f"compile {jax_cell.meta.get('compile_time_s', 0.0):.2f}s metered "
          f"separately from wall {jax_cell.wall_time_s * 1e3:.2f}ms")
    assert jax_cell.sm_trace == py_cell.sm_trace        # bit-identical schedule
    assert jax_cell.cycles == py_cell.cycles
    assert jax_cell.stall_breakdown == py_cell.stall_breakdown
    assert jax_cell.mechanism == "sm_jax"

    # --- 10. process tier: 2 shard processes + a warmed compile cache -----------
    # Numpy mechanisms serialize behind the GIL; procs=2 spawns two shard
    # processes and chunks homogeneous numpy groups across them, while jax
    # groups stay affine to one shard (executable-cache locality).  The
    # warm_start directory persists compile work: a restarted service replays
    # the manifest before admitting traffic, so hot signatures never re-trace.
    from repro.engine import as_request

    warm_dir = tempfile.mkdtemp(prefix="repro-quickstart-cache-")
    reqs = [as_request(b, CFG) for b in benches[:4]]
    with SimulationService(default_mechanism="hanoi", procs=2,
                           warm_start=warm_dir) as svc:
        out = svc.run(reqs, timeout=300)                 # chunked across shards
        jx = svc.run(reqs[:2], mechanism="hanoi_jax", timeout=600)  # affine
        st = svc.stats()
    print("\n=== process tier: 2 shards, signature-affine routing ===")
    shard_of = lambda r: r.meta["service"]["shard"]
    print(f"numpy group spread over shards {sorted({shard_of(r) for r in out})}; "
          f"jax group affine to shard {shard_of(jx[0])}")
    print(f"shards: " + " ".join(f"s{s.shard}(pid {s.pid}): {s.completed} ok"
                                 for s in st.shards))
    print(f"compile cache: {st.cache_misses} trace(s) recorded -> {warm_dir}")
    assert all(a.status == b.status for a, b in
               zip(out, (sim.run(r) for r in reqs)))

    # restart: the warmed service serves the same jax signature with ZERO
    # serve-time re-traces (deserialized AOT executable where jaxlib allows)
    with SimulationService(default_mechanism="hanoi_jax", procs=2,
                           warm_start=warm_dir) as svc:
        svc.run(reqs[:2], timeout=600)
        st2 = svc.stats()
    print(f"warm restart: {st2.warm_signatures} sig(s) warmed "
          f"({st2.warm_loaded} deserialized, {st2.warm_retraced} re-traced), "
          f"serve-time traces={st2.cache_misses}")
    assert st2.cache_misses == st2.warm_retraced         # zero re-trace contract

    # --- 11. static analysis: lint -> admission rejection -> similarity ---------
    from repro.analysis import StaticAnalysisError, analyze_program
    from repro.core.programs import fig5_program, fig6_no_break_program

    broken = fig6_no_break_program()                 # Fig 6 minus its BREAK
    report = analyze_program(broken, CFG, name="fig6-no-break")
    print("\n=== static analysis: the Fig 6 ablation fails the verifier ===")
    print(report.render())
    assert not report.ok and "reconvergence" in report.codes()

    # the service refuses it at admission — no shard ever sees the request;
    # the ticket carries the same structured report as its exception
    with SimulationService(default_mechanism="hanoi", workers=1) as svc:
        bad_ticket = svc.submit(broken, CFG, name="fig6-no-break")
        good_ticket = svc.submit(fig6_program(), CFG, name="fig6")
        svc.flush()
        rejection = bad_ticket.exception()
        assert isinstance(rejection, StaticAnalysisError)
        assert not rejection.report.ok
        assert good_ticket.result().ok               # the BREAK makes it legal
        st11 = svc.stats()
    print(f"service admission: submitted={st11.submitted} "
          f"rejected={st11.rejected} completed={st11.completed} "
          f"(the broken program never reached a shard)")
    assert st11.rejected == 1 and st11.failed == 0

    # archived nearest neighbors, ranked from the sidecar index alone —
    # no archive file opened, nothing replayed
    from repro.analysis import fingerprint
    from repro.archive import ArchiveIndex

    with tempfile.TemporaryDirectory() as tmp11:
        arch11 = RotatingJsonlSink(tmp11)
        lab = Simulator("hanoi", sink=arch11)
        for b in make_suite(CFG, datasets=1):
            lab.run(b, CFG)
        arch11.flush()
        arch11.close()
        idx = ArchiveIndex.ensure(tmp11)             # entries carry CFG fps
        ranked = idx.rank_similar(fingerprint(fig5_program()), top=3)
        by_id = {e.run_id: e.program for e in idx.entries}
        print(f"nearest archived control flow to Fig 5 "
              f"({len(idx)} runs indexed, sidecar only):")
        for rid, d in ranked:
            print(f"  {rid}  d={d:.4f}  {by_id[rid]}")
        assert by_id[ranked[0][0]] == "FIG5" and ranked[0][1] == 0.0

    # --- 12. annotation synthesis: strip Fig 5, get the compiler back ---------
    import numpy as np

    from repro.analysis import strip_annotations, synthesize_annotations
    from repro.core.programs import SPINLOCK_NO_YIELD_ASM, fig5_program
    from repro.core.asm import assemble

    print("\n=== annotation synthesis: strip -> resynthesize Fig 5 ===")
    fig5 = fig5_program()
    stripped = strip_annotations(fig5, CFG)
    resynth = synthesize_annotations(stripped.program, CFG)
    print(f"stripped {len(stripped.removed)} annotation instruction(s); "
          f"synthesizer placed {resynth.regions} region(s) back")
    assert resynth.report.ok
    # Fig 5 hand-forces B0 reuse + an R0 spill; the allocator uses two Bx
    # registers instead — same control flow, cleaner annotation.  The
    # DIAMOND kernel round-trips bit-equal, trace included:
    diamond = next(b for b in make_suite(CFG, datasets=1)
                   if b.name == "DIAMOND")
    d_round = synthesize_annotations(
        strip_annotations(diamond.program, CFG).program, CFG)
    assert np.array_equal(d_round.program, np.asarray(diamond.program))
    ta = sim.run(diamond.program, CFG).trace
    tb = sim.run(d_round.program, CFG).trace
    assert ta == tb
    print("DIAMOND: strip -> synthesize is bit-equal (trace identical)")

    # service auto-repair: the YIELD-less spinlock is rejected under
    # strict admission — unless auto_annotate routes it through the
    # synthesizer, which inserts the YIELD and admits the repair
    spin_hang = assemble(SPINLOCK_NO_YIELD_ASM)
    with SimulationService(default_mechanism="hanoi", workers=1,
                           verify="strict", auto_annotate=True) as svc:
        t12 = svc.submit(spin_hang, CFG, name="spinlock-no-yield")
        svc.flush()
        repaired_res = t12.result()
        st12 = svc.stats()
    assert repaired_res.ok and int(repaired_res.mem[1]) == W
    print(f"service auto-repair: repaired={st12.repaired} rejected="
          f"{st12.rejected} -> spinlock completed {int(repaired_res.mem[1])}"
          f"/{W} critical sections (YIELD synthesized at admission)")
    assert st12.repaired == 1 and st12.rejected == 0

    print("\nquickstart OK")


if __name__ == "__main__":   # required: section 10 spawns processes,
    main()                   # and spawn children re-import this file
