"""Batched serving example: prefill + greedy decode with ring KV caches
(windowed layers), recurrent states (RG-LRU / RWKV) — the same decode_step
the decode_32k / long_500k dry-run cells lower to the production mesh.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-3b]
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b",
                    help="any decoder arch (smoke config)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()
    res = serve(args.arch, smoke=True, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"[example] {args.arch}: generated {res['generated'].shape[1]} "
          f"tokens x {args.batch} seqs in {res['wall_s']:.2f}s "
          f"({res['tokens_per_s']:.1f} tok/s)")
    print("[example] first rows:", res["generated"][:2, :8].tolist())


if __name__ == "__main__":
    main()
