; Fig 3/7 spinlock: ATOMCAS poll with compiler-placed YIELD.
; Lint it:  PYTHONPATH=src python -m repro.analysis examples/spinlock.asm --fingerprint
    MOV R0, 0           ; mutex address
    MOV R1, 1           ; counter address
    MOV R3, 0           ; CAS compare value
    MOV R4, 1           ; CAS swap value
    BSSY B0, esync
loop:
    YIELD               ; SS VI-C: switch to the sibling (lock holder) path
    ATOMCAS R2, [R0], R3, R4
    ISETP.NE P0, R2, 0  ; P0 true -> failed to acquire
    @P0 BRA loop
    LDG R5, [R1]        ; critical section: counter++ (non-atomic on purpose)
    IADDI R5, R5, 1
    STG [R1], R5
    ATOMEXCH R6, [R0], R3   ; release the lock
esync:
    BSYNC B0
    EXIT
